//! Minimal recursive-descent JSON parser — just enough for
//! `artifacts/manifest.json` (objects, arrays, strings, numbers, bools,
//! null; no surrogate-pair escapes).  Replaces serde_json in this offline
//! build; the parser is fully tested below and fuzzed by the property
//! suite in rust/tests/prop_invariants.rs.

use std::collections::BTreeMap;
use std::fmt;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// The `null` literal.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always held as `f64`).
    Num(f64),
    /// A string, with escapes decoded.
    Str(String),
    /// An array of values.
    Arr(Vec<Json>),
    /// An object; keys are sorted (`BTreeMap`) for deterministic iteration.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The string payload, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric payload, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The boolean payload, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The numeric payload truncated to `usize` (manifest dims/shapes).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// The element slice, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize with 2-space indentation (committed artifacts like
    /// `BENCH_sim_scale.json` diff nicely across PRs).  The compact form
    /// is the [`std::fmt::Display`] impl; both round-trip through
    /// [`parse`].  Non-finite numbers serialize as `null` (JSON has no
    /// NaN/inf).
    pub fn to_pretty_string(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        let pad = |out: &mut String, d: usize| {
            for _ in 0..d {
                out.push_str("  ");
            }
        };
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    pad(out, depth + 1);
                    x.write_pretty(out, depth + 1);
                    out.push_str(if i + 1 < v.len() { ",\n" } else { "\n" });
                }
                pad(out, depth);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, x)) in m.iter().enumerate() {
                    pad(out, depth + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    x.write_pretty(out, depth + 1);
                    out.push_str(if i + 1 < m.len() { ",\n" } else { "\n" });
                }
                pad(out, depth);
                out.push('}');
            }
            other => {
                let _ = write!(out, "{other}");
            }
        }
    }
}

/// Compact serialization; round-trips through [`parse`] (object keys are
/// `BTreeMap`-sorted, so output is deterministic).
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) if n.is_finite() => write!(f, "{n}"),
            Json::Num(_) => f.write_str("null"),
            Json::Str(s) => {
                let mut out = String::new();
                write_escaped(&mut out, s);
                f.write_str(&out)
            }
            Json::Arr(v) => {
                f.write_str("[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{x}")?;
                }
                f.write_str("]")
            }
            Json::Obj(m) => {
                f.write_str("{")?;
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    let mut key = String::new();
                    write_escaped(&mut key, k);
                    write!(f, "{key}:{x}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    /// Byte offset into the input where parsing failed.
    pub at: usize,
    /// Human-readable description of what was expected.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let b = text.as_bytes();
    let mut p = Parser { b, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != b.len() {
        return Err(p.err("trailing garbage"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { at: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000C}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // Copy one UTF-8 scalar (multi-byte safe).
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid UTF-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let doc = r#"{
            "format": "hlo-text",
            "artifacts": [
                {"name": "nbody_step", "file": "nbody_step.hlo.txt",
                 "inputs": [{"shape": [1024, 3], "dtype": "f32"}],
                 "outputs": [{"shape": [1024, 3], "dtype": "f32"}]}
            ]
        }"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("format").unwrap().as_str(), Some("hlo-text"));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        let shape = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(1024));
    }

    #[test]
    fn scalars() {
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn nested_arrays() {
        let v = parse("[[1,2],[3]]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[0].as_arr().unwrap().len(), 2);
        assert_eq!(a[1].as_arr().unwrap()[0].as_f64(), Some(3.0));
    }

    #[test]
    fn unicode_and_escapes() {
        assert_eq!(parse("\"caf\\u00e9\"").unwrap(), Json::Str("café".into()));
        assert_eq!(parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("hello").is_err());
        assert!(parse("{}extra").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn writer_round_trips() {
        let doc = r#"{"a": [1, 2.5, -3e2], "b": {"nested": true}, "s": "q\"\n\\t", "z": null}"#;
        let v = parse(doc).unwrap();
        let compact = v.to_string();
        assert_eq!(parse(&compact).unwrap(), v, "compact: {compact}");
        let pretty = v.to_pretty_string();
        assert_eq!(parse(&pretty).unwrap(), v, "pretty:\n{pretty}");
        assert!(pretty.contains('\n') && pretty.contains("  "));
    }

    #[test]
    fn writer_escapes_and_nonfinite() {
        let v = Json::Str("a\"b\\c\nd\u{1}".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn writer_deterministic_key_order() {
        let a = parse(r#"{"b":1,"a":2}"#).unwrap();
        let b = parse(r#"{"a":2,"b":1}"#).unwrap();
        assert_eq!(a.to_string(), b.to_string());
        assert_eq!(a.to_string(), r#"{"a":2,"b":1}"#);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }
}

//! Tiny CLI argument helper (clap is unavailable offline): positional
//! subcommand + `--flag value` / `--flag` options with typed getters.

use std::collections::BTreeMap;

/// Parsed arguments: one optional subcommand, then flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag word (e.g. `bench` in `repro bench fig9`).
    pub subcommand: Option<String>,
    /// Non-flag words after the subcommand, in order.
    pub positionals: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator (usually `std::env::args().skip(1)`).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Self {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // --k=v or --k v or boolean --k
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positionals.push(a);
            }
        }
        out
    }

    /// Raw value of `--name`, if the flag was given.
    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// Whether `--name` was given (boolean flags store `"true"`).
    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    /// `--name` parsed as `usize`, or `default` when absent/unparseable.
    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.flag(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// `--name` parsed as `f64`, or `default` when absent/unparseable.
    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.flag(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// `--name` parsed as `u64` (RNG seeds), or `default` when
    /// absent/unparseable.
    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.flag(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    /// `--name` as a string, or `default` when absent.
    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.flag(name).unwrap_or(default)
    }

    /// `--name` parsed as `T`: `Ok(None)` when the flag is absent,
    /// `Err` on a malformed value.  The strict counterpart of the
    /// defaulting getters above — used where silently falling back would
    /// mask a typo (e.g. `repro fleet --jobs eight`).  Note a bare
    /// boolean `--name` stores the value `"true"`, which is malformed
    /// for numeric `T` and therefore also an error.
    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> anyhow::Result<Option<T>> {
        match self.flag(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("--{name}: invalid value {v:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(words: &[&str]) -> Args {
        Args::parse(words.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["run", "--app", "xpic", "--nodes", "8", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get_str("app", "?"), "xpic");
        assert_eq!(a.get_usize("nodes", 0), 8);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse(&["bench", "--name=fig9"]);
        assert_eq!(a.get_str("name", "?"), "fig9");
    }

    #[test]
    fn positionals_after_subcommand() {
        let a = parse(&["bench", "fig3", "fig4"]);
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.positionals, vec!["fig3", "fig4"]);
    }

    #[test]
    fn defaults_on_missing_or_bad() {
        let a = parse(&["run", "--nodes", "xyz"]);
        assert_eq!(a.get_usize("nodes", 7), 7);
        assert_eq!(a.get_f64("frac", 0.5), 0.5);
        assert_eq!(a.get_u64("seed", 42), 42);
    }

    #[test]
    fn u64_seed_parses() {
        let a = parse(&["run", "--seed", "18446744073709551615"]);
        assert_eq!(a.get_u64("seed", 0), u64::MAX);
    }

    #[test]
    fn empty() {
        let a = parse(&[]);
        assert!(a.subcommand.is_none());
        assert!(a.positionals.is_empty());
    }

    #[test]
    fn duplicate_flag_last_value_wins() {
        let a = parse(&["run", "--nodes", "4", "--nodes", "9"]);
        assert_eq!(a.get_usize("nodes", 0), 9);
        let b = parse(&["run", "--mode=a", "--mode", "b"]);
        assert_eq!(b.get_str("mode", "?"), "b");
    }

    #[test]
    fn boolean_then_flag_does_not_consume_the_next_flag() {
        // `--verbose --nodes 4`: --verbose must stay boolean, not eat
        // `--nodes` as its value.
        let a = parse(&["run", "--verbose", "--nodes", "4"]);
        assert!(a.has("verbose"));
        assert_eq!(a.flag("verbose"), Some("true"));
        assert_eq!(a.get_usize("nodes", 0), 4);
    }

    #[test]
    fn defaulting_getters_swallow_malformed_values() {
        // The lenient getters fall back silently on every malformed
        // spelling a sweep could produce...
        let a = parse(&["run", "--nodes", "4x", "--frac", "half", "--seed", "-1"]);
        assert_eq!(a.get_usize("nodes", 7), 7);
        assert_eq!(a.get_f64("frac", 0.25), 0.25);
        assert_eq!(a.get_u64("seed", 3), 3);
        // ...while get_str hands back the raw word.
        assert_eq!(a.get_str("nodes", "?"), "4x");
    }

    #[test]
    fn get_parsed_strict_error_paths() {
        let a = parse(&["fleet", "--jobs", "eight", "--mtbf", "3600", "--dry-run"]);
        // Malformed value: a real error naming the flag.
        let err = a.get_parsed::<usize>("jobs").unwrap_err();
        assert!(err.to_string().contains("--jobs"), "err={err}");
        assert!(err.to_string().contains("eight"), "err={err}");
        // Well-formed value parses; absent flag is Ok(None).
        assert_eq!(a.get_parsed::<f64>("mtbf").unwrap(), Some(3600.0));
        assert_eq!(a.get_parsed::<u64>("seed").unwrap(), None);
        // A bare boolean flag is malformed for numeric targets.
        assert!(a.get_parsed::<usize>("dry-run").is_err());
    }

    #[test]
    fn get_parsed_duplicate_takes_last() {
        let a = parse(&["fleet", "--jobs", "3", "--jobs", "12"]);
        assert_eq!(a.get_parsed::<usize>("jobs").unwrap(), Some(12));
        // Last value malformed -> the error wins, even after a good one.
        let b = parse(&["fleet", "--jobs", "3", "--jobs", "x"]);
        assert!(b.get_parsed::<usize>("jobs").is_err());
    }

    #[test]
    fn negative_word_is_a_value_not_a_flag() {
        // "-5" does not start with "--", so it is consumed as the value.
        let a = parse(&["run", "--offset", "-5"]);
        assert_eq!(a.flag("offset"), Some("-5"));
        assert_eq!(a.get_parsed::<i64>("offset").unwrap(), Some(-5));
        // ...but u64 rejects it (seeds must be non-negative).
        assert!(a.get_parsed::<u64>("offset").is_err());
    }
}

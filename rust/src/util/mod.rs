//! Small in-tree utilities replacing unavailable external crates (this
//! build environment is offline; see Cargo.toml).  Currently: a minimal
//! JSON parser for the artifact manifest and a tiny CLI argument helper.

pub mod cli;
pub mod json;

//! Ring-buffer send/receive engine — the libRMA/libNAM transfer discipline.
//!
//! Paper Section II-B2: *"Reading and writing is performed via send and
//! receive buffers organized in a ring structure.  The EXTOLL/NAM
//! notification mechanism is used to handle the buffer space, i.e. to free
//! up locations when data has been transmitted (put) or received (get)."*
//!
//! This module implements that credit scheme as a real data structure used
//! by `nam::LibNam`: a fixed number of fixed-size slots; producers claim
//! slots, transfers fill them, notifications retire them.  Messages larger
//! than a slot are fragmented; the ring going full is what throttles a
//! producer that outruns the consumer (visible as the sub-peak bandwidth
//! of small messages in Fig. 3).

/// A fixed-slot ring with credit-based flow control.
#[derive(Debug, Clone)]
pub struct RingBuffer {
    slot_bytes: usize,
    slots: usize,
    /// Sequence number of the next slot to claim.
    head: u64,
    /// Sequence number of the oldest un-retired slot.
    tail: u64,
    /// Messages currently resident: (seq, len) pairs in claim order.
    inflight: std::collections::VecDeque<(u64, usize)>,
}

/// Error returned when the ring has no free slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RingFull;

impl RingBuffer {
    pub fn new(slots: usize, slot_bytes: usize) -> Self {
        assert!(slots > 0 && slot_bytes > 0);
        Self {
            slot_bytes,
            slots,
            head: 0,
            tail: 0,
            inflight: std::collections::VecDeque::new(),
        }
    }

    pub fn slot_bytes(&self) -> usize {
        self.slot_bytes
    }

    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Slots currently free.
    pub fn free_slots(&self) -> usize {
        self.slots - (self.head - self.tail) as usize
    }

    /// Number of slots a message of `len` bytes needs.
    pub fn slots_needed(&self, len: usize) -> usize {
        len.div_ceil(self.slot_bytes).max(1)
    }

    /// Claim space for one message; returns its sequence number.
    pub fn claim(&mut self, len: usize) -> Result<u64, RingFull> {
        let need = self.slots_needed(len);
        if need > self.free_slots() {
            return Err(RingFull);
        }
        let seq = self.head;
        self.head += need as u64;
        self.inflight.push_back((seq, len));
        Ok(seq)
    }

    /// Retire the *oldest* in-flight message (notification arrived).
    /// Returns (seq, len).  Notifications are ordered on EXTOLL, so
    /// in-order retirement matches the hardware.
    pub fn retire_oldest(&mut self) -> Option<(u64, usize)> {
        let (seq, len) = self.inflight.pop_front()?;
        debug_assert_eq!(seq, self.tail);
        self.tail += self.slots_needed(len) as u64;
        Some((seq, len))
    }

    /// Messages still in flight.
    pub fn in_flight(&self) -> usize {
        self.inflight.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inflight.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn claim_and_retire_roundtrip() {
        let mut r = RingBuffer::new(4, 1024);
        let s0 = r.claim(100).unwrap();
        let s1 = r.claim(2048).unwrap(); // 2 slots
        assert_eq!(s0, 0);
        assert_eq!(s1, 1);
        assert_eq!(r.free_slots(), 1);
        assert_eq!(r.retire_oldest(), Some((0, 100)));
        assert_eq!(r.free_slots(), 2);
        assert_eq!(r.retire_oldest(), Some((1, 2048)));
        assert_eq!(r.free_slots(), 4);
        assert!(r.is_empty());
    }

    #[test]
    fn full_ring_rejects() {
        let mut r = RingBuffer::new(2, 512);
        r.claim(512).unwrap();
        r.claim(1).unwrap();
        assert_eq!(r.claim(1), Err(RingFull));
        r.retire_oldest().unwrap();
        assert!(r.claim(1).is_ok());
    }

    #[test]
    fn zero_len_message_takes_one_slot() {
        let mut r = RingBuffer::new(2, 512);
        r.claim(0).unwrap();
        assert_eq!(r.free_slots(), 1);
    }

    #[test]
    fn large_message_fragments() {
        let mut r = RingBuffer::new(8, 1024);
        assert_eq!(r.slots_needed(8192), 8);
        r.claim(8192).unwrap();
        assert_eq!(r.free_slots(), 0);
        assert_eq!(r.claim(1), Err(RingFull));
    }

    #[test]
    fn oversized_message_never_fits() {
        let mut r = RingBuffer::new(4, 1024);
        assert_eq!(r.claim(5000), Err(RingFull)); // needs 5 of 4 slots
        assert_eq!(r.free_slots(), 4); // claim must not leak space
    }

    #[test]
    fn sequences_monotone() {
        let mut r = RingBuffer::new(16, 256);
        let mut last = None;
        for i in 0..8 {
            let s = r.claim(100 + i).unwrap();
            if let Some(l) = last {
                assert!(s > l);
            }
            last = Some(s);
        }
    }
}

//! EXTOLL Tourmalet fabric model: RDMA put/get, notifications, ring buffers.
//!
//! The DEEP-ER prototype runs one uniform EXTOLL fabric across Cluster,
//! Booster, storage and the NAM boards (paper Section II-B, Table I):
//! 100 Gbit/s (12.5 GB/s) per link, ~1.0 us MPI latency on the Cluster and
//! ~1.8 us on the Booster (KNL's slower uncore).  The fabric's RDMA engine
//! (libRMA) moves data without a remote CPU — the property the NAM builds
//! on.
//!
//! Model: every endpoint owns a TX and an RX port resource at link speed;
//! the switch *interior* between the ports is a [`TopologySpec`] — one
//! shared backplane for the 24-node prototype, or a generated shape from
//! the topology zoo (fat-tree leaves + oversubscribed uplinks, dragonfly
//! groups + tapered globals, parallel rails, an asymmetric Cluster/Booster
//! split behind a bridge, or a two-tier leaf/top switch).  A transfer is a
//! [`crate::sim`] flow routed `src.tx -> interior… -> dst.rx`, so incast
//! (many nodes writing to two storage servers, Fig. 6), spine
//! oversubscription and the NAM's two-link bound (Fig. 9) all emerge from
//! resource contention.

pub mod ring;

use crate::sim::{FlowId, ResId, Sim, SimTime};

/// 100 Gbit/s Tourmalet link payload bandwidth, bytes/s.
pub const TOURMALET_BW: f64 = 12.5e9;
/// MPI half-round-trip latency on the Cluster side (Table I).
pub const LAT_CLUSTER: SimTime = 1.0e-6;
/// MPI half-round-trip latency on the Booster side (Table I).
pub const LAT_BOOSTER: SimTime = 1.8e-6;
/// Per-message software/NIC injection overhead (descriptor + doorbell).
pub const MSG_OVERHEAD: SimTime = 0.15e-6;

/// Named, parameterized fabric interior shape (DESIGN.md section 13).
///
/// Endpoints are grouped by their registration index (leaf = `index /
/// arity`, group = `index / group_size`, …), which is deterministic
/// because [`crate::system::Machine::build`] registers nodes in a fixed
/// order.  [`TopologySpec::label`] renders the canonical
/// `family[:params]` name that `system::zoo::by_name` parses back.
#[derive(Debug, Clone, PartialEq)]
pub enum TopologySpec {
    /// One shared switching resource — the original single-backplane model.
    Flat {
        /// Aggregate switching capacity, bytes/s.
        backplane_bw: f64,
    },
    /// Two-level fat-tree: `arity` endpoints per leaf crossbar (the xbar is
    /// non-blocking at `arity * link_bw`); each leaf's uplink into the
    /// spine carries `arity * link_bw / oversub`, so `oversub > 1` models
    /// spine oversubscription.  Cross-leaf routes traverse both leaves'
    /// xbars and uplinks.
    FatTree { arity: usize, link_bw: f64, oversub: f64 },
    /// Dragonfly groups: `group_size` endpoints per group router
    /// (`group_size * link_bw`); the group's global-link budget is the
    /// router capacity divided by `taper`.  Inter-group routes traverse
    /// both routers and both global-link budgets.
    Dragonfly { group_size: usize, link_bw: f64, taper: f64 },
    /// `rails` parallel backplanes of `rail_bw` each; a transfer is pinned
    /// to rail `(src + dst) % rails`, so floors/ceilings must be enforced
    /// per rail rather than on one shared resource.
    MultiRail { rails: usize, rail_bw: f64 },
    /// Asymmetric Cluster/Booster split: endpoints in
    /// `booster_start..booster_end` sit behind the booster-side switch,
    /// everything else (cluster nodes, storage, MDS, NAM) behind the
    /// cluster-side switch; cross-side traffic funnels through a bridge of
    /// `bridge_bw`.
    Split {
        booster_start: usize,
        booster_end: usize,
        cluster_bw: f64,
        booster_bw: f64,
        bridge_bw: f64,
    },
    /// Tiered two-level switch: `leaf_ports` endpoints per leaf switch of
    /// `leaf_bw`; all cross-leaf traffic shares one top switch of `top_bw`.
    Tiered { leaf_ports: usize, leaf_bw: f64, top_bw: f64 },
}

impl TopologySpec {
    /// Canonical `family[:params]` label.  `system::zoo::by_name`
    /// round-trips every label this produces.
    pub fn label(&self) -> String {
        match self {
            TopologySpec::Flat { .. } => "flat".to_string(),
            TopologySpec::FatTree { arity, oversub, .. } => {
                format!("fat-tree:{oversub},{arity}")
            }
            TopologySpec::Dragonfly { group_size, taper, .. } => {
                format!("dragonfly:{group_size},{taper}")
            }
            TopologySpec::MultiRail { rails, .. } => format!("multi-rail:{rails}"),
            TopologySpec::Split { booster_start, booster_end, .. } => {
                format!("split:{},{}", booster_start, booster_end - booster_start)
            }
            TopologySpec::Tiered { leaf_ports, .. } => format!("tiered:{leaf_ports}"),
        }
    }
}

/// One fabric endpoint (a node NIC, a storage server NIC, a NAM link pair).
#[derive(Debug, Clone, Copy)]
pub struct Endpoint {
    pub tx: ResId,
    pub rx: ResId,
    /// Endpoint-side injection latency.
    pub latency: SimTime,
}

/// The realized switch interior: the sim resources backing a
/// [`TopologySpec`].  Leaf/group resources are created lazily as endpoint
/// registration crosses each arity boundary, so the same spec works for
/// any machine size.
#[derive(Debug)]
enum Interior {
    Flat {
        backplane: ResId,
    },
    FatTree {
        arity: usize,
        link_bw: f64,
        oversub: f64,
        xbars: Vec<ResId>,
        uplinks: Vec<ResId>,
    },
    Dragonfly {
        group_size: usize,
        link_bw: f64,
        taper: f64,
        routers: Vec<ResId>,
        globals: Vec<ResId>,
    },
    MultiRail {
        rails: Vec<ResId>,
    },
    Split {
        booster_start: usize,
        booster_end: usize,
        cluster: ResId,
        booster: ResId,
        bridge: ResId,
    },
    Tiered {
        leaf_ports: usize,
        leaf_bw: f64,
        leaves: Vec<ResId>,
        top: ResId,
    },
}

/// The fabric: endpoints plus the switch interior between them.
#[derive(Debug)]
pub struct Fabric {
    interior: Interior,
    endpoints: Vec<Endpoint>,
}

/// Handle to a registered endpoint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EpId(pub usize);

impl Fabric {
    /// Flat fabric: `backplane_bw` is the aggregate switching capacity.
    /// The 24-node DEEP-ER rack is non-blocking (set >= sum of links);
    /// QPACE3's torus bisection is capacity-limited.
    pub fn new(sim: &mut Sim, backplane_bw: f64) -> Self {
        Self::with_topology(sim, &TopologySpec::Flat { backplane_bw })
    }

    /// Build the switch interior for `spec`.  Per-leaf/per-group resources
    /// are created lazily as endpoints register; rails, split switches and
    /// the tiered top switch exist up front.
    pub fn with_topology(sim: &mut Sim, spec: &TopologySpec) -> Self {
        let interior = match *spec {
            TopologySpec::Flat { backplane_bw } => Interior::Flat {
                backplane: sim.resource("fabric:backplane", backplane_bw),
            },
            TopologySpec::FatTree { arity, link_bw, oversub } => {
                assert!(arity >= 1 && oversub > 0.0, "fat-tree: arity >= 1, oversub > 0");
                Interior::FatTree { arity, link_bw, oversub, xbars: Vec::new(), uplinks: Vec::new() }
            }
            TopologySpec::Dragonfly { group_size, link_bw, taper } => {
                assert!(group_size >= 1 && taper > 0.0, "dragonfly: group_size >= 1, taper > 0");
                Interior::Dragonfly { group_size, link_bw, taper, routers: Vec::new(), globals: Vec::new() }
            }
            TopologySpec::MultiRail { rails, rail_bw } => {
                assert!(rails >= 1, "multi-rail: rails >= 1");
                Interior::MultiRail {
                    rails: (0..rails)
                        .map(|i| sim.resource(format!("fabric:rail{i}"), rail_bw))
                        .collect(),
                }
            }
            TopologySpec::Split { booster_start, booster_end, cluster_bw, booster_bw, bridge_bw } => {
                assert!(booster_start <= booster_end, "split: empty or forward booster range");
                Interior::Split {
                    booster_start,
                    booster_end,
                    cluster: sim.resource("fabric:cluster-sw", cluster_bw),
                    booster: sim.resource("fabric:booster-sw", booster_bw),
                    bridge: sim.resource("fabric:bridge", bridge_bw),
                }
            }
            TopologySpec::Tiered { leaf_ports, leaf_bw, top_bw } => {
                assert!(leaf_ports >= 1, "tiered: leaf_ports >= 1");
                Interior::Tiered {
                    leaf_ports,
                    leaf_bw,
                    leaves: Vec::new(),
                    top: sim.resource("fabric:top", top_bw),
                }
            }
        };
        Self { interior, endpoints: Vec::new() }
    }

    /// Register an endpoint with `link_bw` per direction and endpoint latency.
    pub fn endpoint(&mut self, sim: &mut Sim, label: &str, link_bw: f64, latency: SimTime) -> EpId {
        let tx = sim.resource(format!("{label}:tx"), link_bw);
        let rx = sim.resource(format!("{label}:rx"), link_bw);
        self.endpoints.push(Endpoint { tx, rx, latency });
        self.grow(sim);
        EpId(self.endpoints.len() - 1)
    }

    /// Create any leaf/group interior resources the latest endpoint needs.
    fn grow(&mut self, sim: &mut Sim) {
        let n = self.endpoints.len();
        match &mut self.interior {
            Interior::FatTree { arity, link_bw, oversub, xbars, uplinks } => {
                while xbars.len() < n.div_ceil(*arity) {
                    let l = xbars.len();
                    let xbar_bw = *arity as f64 * *link_bw;
                    xbars.push(sim.resource(format!("fabric:leaf{l}:xbar"), xbar_bw));
                    uplinks.push(sim.resource(format!("fabric:leaf{l}:up"), xbar_bw / *oversub));
                }
            }
            Interior::Dragonfly { group_size, link_bw, taper, routers, globals } => {
                while routers.len() < n.div_ceil(*group_size) {
                    let gi = routers.len();
                    let router_bw = *group_size as f64 * *link_bw;
                    routers.push(sim.resource(format!("fabric:grp{gi}:router"), router_bw));
                    globals.push(sim.resource(format!("fabric:grp{gi}:global"), router_bw / *taper));
                }
            }
            Interior::Tiered { leaf_ports, leaf_bw, leaves, .. } => {
                while leaves.len() < n.div_ceil(*leaf_ports) {
                    let l = leaves.len();
                    leaves.push(sim.resource(format!("fabric:leaf{l}"), *leaf_bw));
                }
            }
            Interior::Flat { .. } | Interior::MultiRail { .. } | Interior::Split { .. } => {}
        }
    }

    pub fn endpoint_info(&self, ep: EpId) -> Endpoint {
        self.endpoints[ep.0]
    }

    /// The interior resources a `src -> dst` transfer traverses between
    /// `src.tx` and `dst.rx` (in traversal order).  Call sites that append
    /// extra hops (a device, a NAM memory port) build their route as
    /// `[s.tx] + interior + [d.rx, extra…]`.
    pub fn interior(&self, src: EpId, dst: EpId) -> Vec<ResId> {
        match &self.interior {
            Interior::Flat { backplane } => vec![*backplane],
            Interior::FatTree { arity, xbars, uplinks, .. } => {
                let (ls, ld) = (src.0 / arity, dst.0 / arity);
                if ls == ld {
                    vec![xbars[ls]]
                } else {
                    vec![xbars[ls], uplinks[ls], uplinks[ld], xbars[ld]]
                }
            }
            Interior::Dragonfly { group_size, routers, globals, .. } => {
                let (gs, gd) = (src.0 / group_size, dst.0 / group_size);
                if gs == gd {
                    vec![routers[gs]]
                } else {
                    vec![routers[gs], globals[gs], globals[gd], routers[gd]]
                }
            }
            Interior::MultiRail { rails } => vec![rails[(src.0 + dst.0) % rails.len()]],
            Interior::Split { booster_start, booster_end, cluster, booster, bridge } => {
                let booster_side = |e: usize| e >= *booster_start && e < *booster_end;
                match (booster_side(src.0), booster_side(dst.0)) {
                    (false, false) => vec![*cluster],
                    (true, true) => vec![*booster],
                    (false, true) => vec![*cluster, *bridge, *booster],
                    (true, false) => vec![*booster, *bridge, *cluster],
                }
            }
            Interior::Tiered { leaf_ports, leaves, top } => {
                let (ls, ld) = (src.0 / leaf_ports, dst.0 / leaf_ports);
                if ls == ld {
                    vec![leaves[ls]]
                } else {
                    vec![leaves[ls], *top, leaves[ld]]
                }
            }
        }
    }

    /// Full data route of a `src -> dst` transfer: `src.tx`, the interior,
    /// `dst.rx`.
    pub fn path(&self, src: EpId, dst: EpId) -> Vec<ResId> {
        let s = self.endpoints[src.0];
        let d = self.endpoints[dst.0];
        let mut route = Vec::with_capacity(6);
        route.push(s.tx);
        route.extend(self.interior(src, dst));
        route.push(d.rx);
        route
    }

    /// The interior resources the topology can be contended/shaped on: the
    /// flat backplane, fat-tree uplinks, dragonfly globals, the rails, the
    /// split's three switches, or the tiered top switch.  QoS budgets and
    /// class floors/ceilings are installed per core resource.
    pub fn core_resources(&self) -> Vec<ResId> {
        match &self.interior {
            Interior::Flat { backplane } => vec![*backplane],
            Interior::FatTree { uplinks, .. } => uplinks.clone(),
            Interior::Dragonfly { globals, .. } => globals.clone(),
            Interior::MultiRail { rails } => rails.clone(),
            Interior::Split { cluster, booster, bridge, .. } => {
                vec![*cluster, *bridge, *booster]
            }
            Interior::Tiered { top, .. } => vec![*top],
        }
    }

    /// RDMA put: `bytes` from `src` into `dst` memory.  Completion fires a
    /// notification at the destination (the libRMA/libNAM mechanism used to
    /// manage ring-buffer space) — here completion time *is* the notify.
    pub fn put(&self, sim: &mut Sim, src: EpId, dst: EpId, bytes: f64) -> FlowId {
        let s = self.endpoints[src.0];
        let d = self.endpoints[dst.0];
        let lat = s.latency + d.latency + MSG_OVERHEAD;
        sim.flow(bytes, lat, &self.path(src, dst))
    }

    /// RDMA get: `bytes` pulled by `src` from `dst` memory.  One extra
    /// request half-round-trip before data flows back (data path is
    /// `dst -> src`).
    pub fn get(&self, sim: &mut Sim, src: EpId, dst: EpId, bytes: f64) -> FlowId {
        let s = self.endpoints[src.0];
        let d = self.endpoints[dst.0];
        let lat = 2.0 * d.latency + s.latency + MSG_OVERHEAD;
        sim.flow(bytes, lat, &self.path(dst, src))
    }

    /// Zero-byte notification (doorbell) from `src` to `dst`.
    pub fn notify(&self, sim: &mut Sim, src: EpId, dst: EpId) -> FlowId {
        let s = self.endpoints[src.0];
        let d = self.endpoints[dst.0];
        sim.delay(s.latency + d.latency + MSG_OVERHEAD)
    }

    /// Analytic time for an uncontended transfer (used by collectives).
    pub fn xfer_time(&self, src: EpId, dst: EpId, bytes: f64) -> SimTime {
        let s = self.endpoints[src.0];
        let d = self.endpoints[dst.0];
        let bw = TOURMALET_BW;
        s.latency + d.latency + MSG_OVERHEAD + bytes / bw
    }

    /// The single shared backplane of a [`TopologySpec::Flat`] fabric.
    /// Panics on any other topology — multi-resource interiors have no one
    /// backplane; use [`Fabric::core_resources`] / [`Fabric::interior`].
    pub fn backplane(&self) -> ResId {
        match &self.interior {
            Interior::Flat { backplane } => *backplane,
            _ => panic!(
                "Fabric::backplane() is only defined for the flat topology; \
                 use core_resources()/interior() on zoo topologies"
            ),
        }
    }

    pub fn n_endpoints(&self) -> usize {
        self.endpoints.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_node_fabric() -> (Sim, Fabric, EpId, EpId) {
        let mut sim = Sim::new();
        let mut fab = Fabric::new(&mut sim, 1e12);
        let a = fab.endpoint(&mut sim, "a", TOURMALET_BW, LAT_CLUSTER);
        let b = fab.endpoint(&mut sim, "b", TOURMALET_BW, LAT_CLUSTER);
        (sim, fab, a, b)
    }

    #[test]
    fn put_latency_floor_small_message() {
        let (mut sim, fab, a, b) = two_node_fabric();
        let f = fab.put(&mut sim, a, b, 8.0);
        let t = sim.wait_all(&[f]);
        // ~2x 1.0us endpoint latency + overhead, transfer time negligible.
        assert!(t > 2.0e-6 && t < 3.0e-6, "t={t}");
    }

    #[test]
    fn put_large_message_reaches_link_bw() {
        let (mut sim, fab, a, b) = two_node_fabric();
        let bytes = 1e9;
        let f = fab.put(&mut sim, a, b, bytes);
        let t = sim.wait_all(&[f]);
        let bw = bytes / t;
        assert!(bw > 0.99 * TOURMALET_BW * 0.999, "bw={bw:e}");
    }

    #[test]
    fn get_slower_than_put_for_small_messages() {
        let (mut sim, fab, a, b) = two_node_fabric();
        let p = fab.put(&mut sim, a, b, 64.0);
        let t_put = sim.wait_all(&[p]);
        let g = fab.get(&mut sim, a, b, 64.0);
        let t_get = sim.wait_all(&[g]) - t_put;
        assert!(t_get > t_put, "put={t_put} get={t_get}");
    }

    #[test]
    fn incast_shares_destination_port() {
        // 4 senders into one receiver: each gets ~1/4 of the rx port.
        let mut sim = Sim::new();
        let mut fab = Fabric::new(&mut sim, 1e12);
        let dst = fab.endpoint(&mut sim, "dst", TOURMALET_BW, LAT_CLUSTER);
        let flows: Vec<_> = (0..4)
            .map(|i| {
                let src = fab.endpoint(&mut sim, &format!("s{i}"), TOURMALET_BW, LAT_CLUSTER);
                fab.put(&mut sim, src, dst, 1e9)
            })
            .collect();
        let t = sim.wait_all(&flows);
        let expect = 4e9 / TOURMALET_BW;
        assert!((t - expect).abs() / expect < 0.01, "t={t} expect={expect}");
    }

    #[test]
    fn booster_latency_higher() {
        let mut sim = Sim::new();
        let mut fab = Fabric::new(&mut sim, 1e12);
        let c = fab.endpoint(&mut sim, "c", TOURMALET_BW, LAT_CLUSTER);
        let k = fab.endpoint(&mut sim, "k", TOURMALET_BW, LAT_BOOSTER);
        let f1 = fab.put(&mut sim, c, c, 8.0);
        let t1 = sim.wait_all(&[f1]);
        let f2 = fab.put(&mut sim, c, k, 8.0);
        let t2 = sim.wait_all(&[f2]) - t1;
        assert!(t2 > t1, "cluster={t1} booster={t2}");
    }

    #[test]
    fn constrained_backplane_limits_aggregate() {
        let mut sim = Sim::new();
        let mut fab = Fabric::new(&mut sim, 20e9); // less than 4 links
        let eps: Vec<_> = (0..8)
            .map(|i| fab.endpoint(&mut sim, &format!("n{i}"), TOURMALET_BW, LAT_CLUSTER))
            .collect();
        let flows: Vec<_> = (0..4)
            .map(|i| fab.put(&mut sim, eps[i], eps[i + 4], 1e9))
            .collect();
        let t = sim.wait_all(&flows);
        let agg_bw = 4e9 / t;
        assert!(agg_bw < 20.5e9, "agg={agg_bw:e}");
    }

    fn zoo_fabric(spec: TopologySpec, n: usize) -> (Sim, Fabric, Vec<EpId>) {
        let mut sim = Sim::new();
        let mut fab = Fabric::with_topology(&mut sim, &spec);
        let eps: Vec<_> = (0..n)
            .map(|i| fab.endpoint(&mut sim, &format!("n{i}"), TOURMALET_BW, LAT_CLUSTER))
            .collect();
        (sim, fab, eps)
    }

    #[test]
    fn fat_tree_intra_leaf_avoids_uplink_and_cross_leaf_is_oversubscribed() {
        // arity 4, 2:1 oversub: uplink = 4 * 12.5 / 2 = 25 GB/s.
        let spec = TopologySpec::FatTree { arity: 4, link_bw: TOURMALET_BW, oversub: 2.0 };
        let (mut sim, fab, eps) = zoo_fabric(spec, 8);
        let intra = fab.interior(eps[0], eps[1]);
        assert_eq!(intra.len(), 1, "same leaf: xbar only");
        let cross = fab.interior(eps[0], eps[5]);
        assert_eq!(cross.len(), 4, "cross leaf: xbar, up, up, xbar");
        // 4 cross-leaf senders from leaf 0 share its 25 GB/s uplink.
        let flows: Vec<_> = (0..4).map(|i| fab.put(&mut sim, eps[i], eps[i + 4], 1e9)).collect();
        let t = sim.wait_all(&flows);
        let agg = 4e9 / t;
        assert!(agg < 25.5e9, "uplink must cap the aggregate: {agg:e}");
        assert!(agg > 24.0e9, "uplink should be the only binding hop: {agg:e}");
    }

    #[test]
    fn multi_rail_pins_transfers_by_endpoint_pair() {
        let spec = TopologySpec::MultiRail { rails: 3, rail_bw: 10e9 };
        let (_sim, fab, eps) = zoo_fabric(spec, 6);
        assert_eq!(fab.core_resources().len(), 3);
        let r03 = fab.interior(eps[0], eps[3]);
        let r14 = fab.interior(eps[1], eps[4]);
        let r04 = fab.interior(eps[0], eps[4]);
        assert_eq!(r03, r14, "(0+3)%3 == (1+4)%3: same rail");
        assert_ne!(r03, r04, "(0+3)%3 != (0+4)%3: different rails");
    }

    #[test]
    fn split_bridge_limits_cross_side_traffic_only() {
        let spec = TopologySpec::Split {
            booster_start: 2,
            booster_end: 4,
            cluster_bw: 100e9,
            booster_bw: 100e9,
            bridge_bw: 5e9,
        };
        let (mut sim, fab, eps) = zoo_fabric(spec, 4);
        assert_eq!(fab.interior(eps[0], eps[1]).len(), 1, "cluster-side stays local");
        assert_eq!(fab.interior(eps[2], eps[3]).len(), 1, "booster-side stays local");
        assert_eq!(fab.interior(eps[0], eps[2]).len(), 3, "cross side crosses the bridge");
        let f = fab.put(&mut sim, eps[0], eps[2], 1e9);
        let t = sim.wait_all(&[f]);
        let bw = 1e9 / t;
        assert!(bw < 5.1e9, "bridge must cap cross traffic: {bw:e}");
    }

    #[test]
    fn dragonfly_and_tiered_route_shapes() {
        let spec = TopologySpec::Dragonfly { group_size: 2, link_bw: TOURMALET_BW, taper: 4.0 };
        let (mut sim, fab, eps) = zoo_fabric(spec, 4);
        assert_eq!(fab.interior(eps[0], eps[1]).len(), 1, "intra-group: router only");
        assert_eq!(fab.interior(eps[0], eps[3]).len(), 4, "inter-group: router+global x2");
        assert_eq!(fab.core_resources().len(), 2, "one global budget per group");
        // Tapered global: 2 * 12.5 / 4 = 6.25 GB/s caps an inter-group put.
        let f = fab.put(&mut sim, eps[0], eps[3], 1e9);
        let t = sim.wait_all(&[f]);
        assert!(1e9 / t < 6.5e9);

        let (_sim2, fab2, eps2) =
            zoo_fabric(TopologySpec::Tiered { leaf_ports: 2, leaf_bw: 25e9, top_bw: 10e9 }, 4);
        assert_eq!(fab2.interior(eps2[0], eps2[1]).len(), 1);
        assert_eq!(fab2.interior(eps2[0], eps2[2]).len(), 3);
        assert_eq!(fab2.core_resources().len(), 1, "tiered core is the top switch");
    }

    #[test]
    fn topology_labels_are_canonical() {
        assert_eq!(TopologySpec::Flat { backplane_bw: 1e9 }.label(), "flat");
        assert_eq!(
            TopologySpec::FatTree { arity: 8, link_bw: 1e9, oversub: 2.0 }.label(),
            "fat-tree:2,8"
        );
        assert_eq!(
            TopologySpec::Dragonfly { group_size: 8, link_bw: 1e9, taper: 4.0 }.label(),
            "dragonfly:8,4"
        );
        assert_eq!(TopologySpec::MultiRail { rails: 4, rail_bw: 1e9 }.label(), "multi-rail:4");
        assert_eq!(
            TopologySpec::Split {
                booster_start: 8,
                booster_end: 24,
                cluster_bw: 1e9,
                booster_bw: 1e9,
                bridge_bw: 1e9
            }
            .label(),
            "split:8,16"
        );
        assert_eq!(
            TopologySpec::Tiered { leaf_ports: 8, leaf_bw: 1e9, top_bw: 1e9 }.label(),
            "tiered:8"
        );
    }
}

//! EXTOLL Tourmalet fabric model: RDMA put/get, notifications, ring buffers.
//!
//! The DEEP-ER prototype runs one uniform EXTOLL fabric across Cluster,
//! Booster, storage and the NAM boards (paper Section II-B, Table I):
//! 100 Gbit/s (12.5 GB/s) per link, ~1.0 us MPI latency on the Cluster and
//! ~1.8 us on the Booster (KNL's slower uncore).  The fabric's RDMA engine
//! (libRMA) moves data without a remote CPU — the property the NAM builds
//! on.
//!
//! Model: every endpoint owns a TX and an RX port resource at link speed;
//! a switch backplane resource carries aggregate traffic (non-blocking for
//! the 24-node prototype, capacity-limited for the 672-node QPACE3 torus).
//! A transfer is a [`crate::sim`] flow routed `src.tx -> backplane -> dst.rx`, so
//! incast (many nodes writing to two storage servers, Fig. 6) and the
//! NAM's two-link bound (Fig. 9) emerge from resource contention.

pub mod ring;

use crate::sim::{FlowId, ResId, Sim, SimTime};

/// 100 Gbit/s Tourmalet link payload bandwidth, bytes/s.
pub const TOURMALET_BW: f64 = 12.5e9;
/// MPI half-round-trip latency on the Cluster side (Table I).
pub const LAT_CLUSTER: SimTime = 1.0e-6;
/// MPI half-round-trip latency on the Booster side (Table I).
pub const LAT_BOOSTER: SimTime = 1.8e-6;
/// Per-message software/NIC injection overhead (descriptor + doorbell).
pub const MSG_OVERHEAD: SimTime = 0.15e-6;

/// One fabric endpoint (a node NIC, a storage server NIC, a NAM link pair).
#[derive(Debug, Clone, Copy)]
pub struct Endpoint {
    pub tx: ResId,
    pub rx: ResId,
    /// Endpoint-side injection latency.
    pub latency: SimTime,
}

/// The fabric: endpoints plus a shared backplane.
#[derive(Debug)]
pub struct Fabric {
    backplane: ResId,
    endpoints: Vec<Endpoint>,
}

/// Handle to a registered endpoint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EpId(pub usize);

impl Fabric {
    /// `backplane_bw`: aggregate switching capacity.  The 24-node DEEP-ER
    /// rack is non-blocking (set >= sum of links); QPACE3's torus bisection
    /// is capacity-limited.
    pub fn new(sim: &mut Sim, backplane_bw: f64) -> Self {
        let backplane = sim.resource("fabric:backplane", backplane_bw);
        Self { backplane, endpoints: Vec::new() }
    }

    /// Register an endpoint with `link_bw` per direction and endpoint latency.
    pub fn endpoint(&mut self, sim: &mut Sim, label: &str, link_bw: f64, latency: SimTime) -> EpId {
        let tx = sim.resource(format!("{label}:tx"), link_bw);
        let rx = sim.resource(format!("{label}:rx"), link_bw);
        self.endpoints.push(Endpoint { tx, rx, latency });
        EpId(self.endpoints.len() - 1)
    }

    pub fn endpoint_info(&self, ep: EpId) -> Endpoint {
        self.endpoints[ep.0]
    }

    /// RDMA put: `bytes` from `src` into `dst` memory.  Completion fires a
    /// notification at the destination (the libRMA/libNAM mechanism used to
    /// manage ring-buffer space) — here completion time *is* the notify.
    pub fn put(&self, sim: &mut Sim, src: EpId, dst: EpId, bytes: f64) -> FlowId {
        let s = self.endpoints[src.0];
        let d = self.endpoints[dst.0];
        let lat = s.latency + d.latency + MSG_OVERHEAD;
        sim.flow(bytes, lat, &[s.tx, self.backplane, d.rx])
    }

    /// RDMA get: `bytes` pulled by `src` from `dst` memory.  One extra
    /// request half-round-trip before data flows back.
    pub fn get(&self, sim: &mut Sim, src: EpId, dst: EpId, bytes: f64) -> FlowId {
        let s = self.endpoints[src.0];
        let d = self.endpoints[dst.0];
        let lat = 2.0 * d.latency + s.latency + MSG_OVERHEAD;
        sim.flow(bytes, lat, &[d.tx, self.backplane, s.rx])
    }

    /// Zero-byte notification (doorbell) from `src` to `dst`.
    pub fn notify(&self, sim: &mut Sim, src: EpId, dst: EpId) -> FlowId {
        let s = self.endpoints[src.0];
        let d = self.endpoints[dst.0];
        sim.delay(s.latency + d.latency + MSG_OVERHEAD)
    }

    /// Analytic time for an uncontended transfer (used by collectives).
    pub fn xfer_time(&self, src: EpId, dst: EpId, bytes: f64) -> SimTime {
        let s = self.endpoints[src.0];
        let d = self.endpoints[dst.0];
        let bw = TOURMALET_BW;
        s.latency + d.latency + MSG_OVERHEAD + bytes / bw
    }

    pub fn backplane(&self) -> ResId {
        self.backplane
    }

    pub fn n_endpoints(&self) -> usize {
        self.endpoints.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_node_fabric() -> (Sim, Fabric, EpId, EpId) {
        let mut sim = Sim::new();
        let mut fab = Fabric::new(&mut sim, 1e12);
        let a = fab.endpoint(&mut sim, "a", TOURMALET_BW, LAT_CLUSTER);
        let b = fab.endpoint(&mut sim, "b", TOURMALET_BW, LAT_CLUSTER);
        (sim, fab, a, b)
    }

    #[test]
    fn put_latency_floor_small_message() {
        let (mut sim, fab, a, b) = two_node_fabric();
        let f = fab.put(&mut sim, a, b, 8.0);
        let t = sim.wait_all(&[f]);
        // ~2x 1.0us endpoint latency + overhead, transfer time negligible.
        assert!(t > 2.0e-6 && t < 3.0e-6, "t={t}");
    }

    #[test]
    fn put_large_message_reaches_link_bw() {
        let (mut sim, fab, a, b) = two_node_fabric();
        let bytes = 1e9;
        let f = fab.put(&mut sim, a, b, bytes);
        let t = sim.wait_all(&[f]);
        let bw = bytes / t;
        assert!(bw > 0.99 * TOURMALET_BW * 0.999, "bw={bw:e}");
    }

    #[test]
    fn get_slower_than_put_for_small_messages() {
        let (mut sim, fab, a, b) = two_node_fabric();
        let p = fab.put(&mut sim, a, b, 64.0);
        let t_put = sim.wait_all(&[p]);
        let g = fab.get(&mut sim, a, b, 64.0);
        let t_get = sim.wait_all(&[g]) - t_put;
        assert!(t_get > t_put, "put={t_put} get={t_get}");
    }

    #[test]
    fn incast_shares_destination_port() {
        // 4 senders into one receiver: each gets ~1/4 of the rx port.
        let mut sim = Sim::new();
        let mut fab = Fabric::new(&mut sim, 1e12);
        let dst = fab.endpoint(&mut sim, "dst", TOURMALET_BW, LAT_CLUSTER);
        let flows: Vec<_> = (0..4)
            .map(|i| {
                let src = fab.endpoint(&mut sim, &format!("s{i}"), TOURMALET_BW, LAT_CLUSTER);
                fab.put(&mut sim, src, dst, 1e9)
            })
            .collect();
        let t = sim.wait_all(&flows);
        let expect = 4e9 / TOURMALET_BW;
        assert!((t - expect).abs() / expect < 0.01, "t={t} expect={expect}");
    }

    #[test]
    fn booster_latency_higher() {
        let mut sim = Sim::new();
        let mut fab = Fabric::new(&mut sim, 1e12);
        let c = fab.endpoint(&mut sim, "c", TOURMALET_BW, LAT_CLUSTER);
        let k = fab.endpoint(&mut sim, "k", TOURMALET_BW, LAT_BOOSTER);
        let f1 = fab.put(&mut sim, c, c, 8.0);
        let t1 = sim.wait_all(&[f1]);
        let f2 = fab.put(&mut sim, c, k, 8.0);
        let t2 = sim.wait_all(&[f2]) - t1;
        assert!(t2 > t1, "cluster={t1} booster={t2}");
    }

    #[test]
    fn constrained_backplane_limits_aggregate() {
        let mut sim = Sim::new();
        let mut fab = Fabric::new(&mut sim, 20e9); // less than 4 links
        let eps: Vec<_> = (0..8)
            .map(|i| fab.endpoint(&mut sim, &format!("n{i}"), TOURMALET_BW, LAT_CLUSTER))
            .collect();
        let flows: Vec<_> = (0..4)
            .map(|i| fab.put(&mut sim, eps[i], eps[i + 4], 1e9))
            .collect();
        let t = sim.wait_all(&flows);
        let agg_bw = 4e9 / t;
        assert!(agg_bw < 20.5e9, "agg={agg_bw:e}");
    }
}

//! `repro` — the DEEP-ER reproduction coordinator CLI.
//!
//! ```text
//! repro show-config
//! repro bench <fig3..fig10|fig8-async|table1..table3|all> [--csv] [--seed N]
//! repro bench qos [--iters N] [--csv] [--seed N] [--json PATH]
//! repro bench obs [--jobs N] [--repeats N] [--csv] [--seed N] [--json PATH]
//! repro run [--app nbody|xpic|gershwin|fwi] [--strategy single|partner|buddy|dist-xor|nam-xor]
//!           [--iterations N] [--cp-interval N] [--fail-at I] [--mtbf S] [--seed N]
//!           [--nodes N] [--multilevel] [--async-flush] [--trace-out PATH]
//! repro fleet [--jobs N] [--policy fcfs|backfill] [--seed S] [--mtbf S] [--qos]
//!             [--json PATH] [--trace-out PATH]
//! repro serve [--jobs N] [--arrivals poisson|trace] [--rate HZ] [--queue-cap N]
//!             [--json PATH] [--trace-out PATH]
//! repro e2e [--artifacts DIR]
//! ```

use deeper::apps::{self, run_iterations, run_iterations_multilevel, IterationJob, RunStats};
use deeper::bench;
use deeper::metrics::fmt_time;
use deeper::obs;
use deeper::runtime::{default_artifacts_dir, Runtime, Tensor};
use deeper::sched::{self, FleetConfig, Policy};
use deeper::scr::multilevel::{MultiLevelConfig, MultiLevelScr};
use deeper::scr::{Scr, Strategy};
use deeper::system::failure::FailurePlan;
use deeper::system::faults::FaultPlan;
use deeper::system::{presets, zoo, Machine, NodeKind};
use deeper::util::cli::Args;
use deeper::util::json::Json;

const USAGE: &str = "\
repro — DEEP-ER Cluster-Booster I/O + resiliency reproduction

USAGE:
  repro show-config
  repro bench <fig3..fig10|fig8-async|table1..table3|cb-split|all> [--csv] [--seed N]
  repro bench scale [--sweep N1,N2,..] [--baseline-max N] [--topology NAME]
                    [--threads T1,T2,..] [--json PATH] [--csv] [--seed N]
  repro bench qos [--iters N] [--topology NAME] [--threads N] [--json PATH]
                  [--csv] [--seed N]
  repro bench obs [--jobs N] [--repeats N] [--span-cap N] [--json PATH]
                  [--csv] [--seed N]
  repro run [--app nbody|xpic|gershwin|fwi] [--strategy single|partner|buddy|dist-xor|nam-xor]
            [--iterations N] [--cp-interval N] [--fail-at I] [--mtbf S] [--seed N]
            [--nodes N] [--multilevel] [--async-flush] [--topology NAME] [--threads N]
            [--trace-out PATH]
  repro fleet [--jobs N] [--policy fcfs|backfill] [--seed S] [--mtbf S]
              [--qos] [--faults N] [--resilience reactive|proactive]
              [--topology NAME] [--threads N] [--json PATH] [--trace-out PATH]
  repro serve [--jobs N] [--arrivals poisson|trace] [--rate HZ] [--trace PATH]
              [--policy fcfs|backfill] [--queue-cap N] [--window S]
              [--reserve-depth N] [--qos] [--faults N] [--seed S]
              [--topology NAME] [--threads N] [--json PATH] [--trace-out PATH]
  repro bench fleet [--sweep N1,N2,..] [--mtbf S] [--topology NAME]
                    [--json PATH] [--csv] [--seed N]
  repro bench serve [--jobs N] [--rate HZ] [--queue-cap N] [--topology NAME]
                    [--json PATH] [--csv] [--seed N]
  repro bench resilience [--jobs N] [--faults N] [--topology NAME]
                         [--json PATH] [--csv] [--seed N]
  repro split [--iterations N]          (Cluster-Booster division of labour)
  repro e2e [--artifacts DIR]

  --async-flush  run the L1->L2 checkpoint promotion as a background flush
                 overlapped with compute (implies --multilevel)
  --mtbf S       sample node failures with an exponential per-node MTBF of
                 S seconds (reproducible via --seed)
  --seed N       seed for stochastic failure schedules (default 0xDEE9E5)

  fleet co-schedules N synthetic jobs (mixed apps, node splits, checkpoint
  strategies, priorities drawn from --seed) on one shared DEEP-ER
  prototype machine under the chosen policy; node failures kill the
  owning job, restart it from its best settled checkpoint and requeue it.
  bench fleet sweeps job counts under both policies and writes the
  BENCH_fleet.json trajectory artifact (--json PATH).

  serve runs the fleet in *service mode* (DESIGN.md section 16): an open
  arrival process — Poisson at --rate jobs/s, or a --trace file with one
  arrival offset (seconds) per line — feeds --jobs synthetic submissions
  through rolling admission.  An arrival finding --queue-cap jobs already
  queued is rejected; admitted jobs run to completion under the chosen
  policy (backfill plans against an incrementally maintained capacity
  profile; --reserve-depth bounds how many queued jobs hold reservations
  per round).  The report measures steady-state SLOs — per-class p50/p99
  queue waits, rolling --window utilization windows, the rejection rate —
  and `--json` writes the byte-deterministic BENCH_serve.json artifact.
  bench serve wraps one such run as an exhibit with the same artifact.

  bench scale sweeps the DES engine over growing concurrent-flow counts
  (default 1000,10000,100000), timing it against the naive reference
  engine, and writes the BENCH_sim_scale.json trajectory artifact
  (--json PATH, default BENCH_sim_scale.json).  With --csv every bench
  exhibit also prints a trailing `# engine: <events> events, <rate>` line.

  bench qos measures a latency-sensitive job's p50/p95/p99 exchange-phase
  slowdown while a neighbor flushes checkpoints over an oversubscribed
  shared fabric, with and without traffic shaping (CkptFlush ceiling +
  Exchange floor/weight), and writes BENCH_qos.json (--json PATH).
  --qos on `repro fleet` enables admission control: jobs' declared
  exchange guarantees are admitted against a fabric-core budget at
  dispatch and installed as rate floors while they run.

  --trace-out PATH (on run/fleet/serve) records a deterministic trace
  on the *virtual* sim clock (DESIGN.md section 17) and writes it as
  Chrome trace-event JSON, loadable in Perfetto or chrome://tracing:
  pid 0 is the system (scheduler / engine / serve / qos lanes), pid
  j+1 is fleet job j (phase / scr / flush / io lanes).  Timestamps are
  sim time, so the file is byte-deterministic for a fixed seed, and
  tracing never perturbs results — reports are byte-identical traced
  vs untraced.  bench obs measures that: it runs the same fleet with
  and without a trace installed, checks report equality, and writes
  BENCH_obs.json (traced vs untraced wall time, span/counter totals).

  --faults N injects a seeded *correlated* degraded-mode schedule
  (DESIGN.md section 15): link degradations and straggler windows that
  end in a fail-stop kill of the same node, plus standalone checkpoint
  corruptions.  --resilience picks how the fleet responds: `reactive`
  (default) waits for the kill and rolls back to the last verified
  checkpoint; `proactive` treats degradations as precursors — a
  health monitor raises per-node suspicion, suspect jobs are
  preemptively checkpointed and migrated to healthy spares, and new
  placements avoid suspects.  bench resilience runs the same mix under
  the same schedule with both policies and writes BENCH_resilience.json
  (wasted work, migrations, makespan, per-mode fault counts).

  --topology NAME selects a machine from the topology zoo (DESIGN.md
  section 13) instead of the flat DEEP-ER prototype fabric.  Names are
  `family[:params]`; missing parameters take defaults:
    flat                     single shared backplane (the prototype)
    fat-tree:OVERSUB,ARITY   leaf crossbars + oversubscribed uplinks
    dragonfly:GROUP,TAPER    router groups + tapered global links
    multi-rail:RAILS         parallel backplanes, pinned per node pair
    split:NCLUSTER,NBOOSTER  asymmetric Cluster/Booster sides + bridge
    tiered:PORTS             leaf switches under one top switch
  e.g. `repro bench qos --topology fat-tree:2` (2:1 oversubscription).
  The selected canonical name is recorded in every JSON artifact.

  --threads N    worker threads for the component-parallel DES engine
  (DESIGN.md section 14).  1 — the default — is bit-identical to the
  serial engine; N>1 shards closed-horizon regions across connected
  components with identical virtual-time results.  `bench scale` takes a
  comma list and sweeps it (the `threads` axis of BENCH_sim_scale.json,
  schema v2); with --csv the `# engine:` line appends per-worker event
  counters.
";

fn parse_strategy(s: &str) -> anyhow::Result<Strategy> {
    Ok(match s {
        "single" => Strategy::Single,
        "partner" => Strategy::Partner,
        "buddy" => Strategy::Buddy,
        "dist-xor" | "distxor" => Strategy::DistXor,
        "nam-xor" | "namxor" => Strategy::NamXor,
        _ => anyhow::bail!("unknown strategy {s}"),
    })
}

/// Print one exhibit group, timing its construction so `--csv` can append
/// the `# engine:` stats line (events from the process-wide counter —
/// exhibits build many simulators internally).
fn print_exhibits(name: &str, csv: bool, seed: u64) -> Option<()> {
    let events_before = deeper::sim::events_total();
    let t0 = std::time::Instant::now();
    let exhibits = bench::by_name(name, seed)?;
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    let events = deeper::sim::events_total() - events_before;
    for e in exhibits {
        println!("{}", if csv { e.render_csv() } else { e.render() });
    }
    if csv {
        println!("# engine: {events} events, {:.3e} events/s", events as f64 / wall);
    }
    Some(())
}

/// Parse a `--sweep N1,N2,..` comma list (shared by the scale and fleet
/// bench commands); `noun` names the counted thing in error messages.
fn parse_sweep(args: &Args, noun: &str, default: &[usize]) -> anyhow::Result<Vec<usize>> {
    let sweep: Vec<usize> = match args.flag("sweep") {
        Some(s) => s
            .split(',')
            .map(|w| {
                let w = w.trim();
                w.parse()
                    .map_err(|_| anyhow::anyhow!("--sweep: invalid {noun} {w:?}"))
            })
            .collect::<anyhow::Result<_>>()?,
        None => default.to_vec(),
    };
    anyhow::ensure!(!sweep.is_empty(), "--sweep needs a comma-separated list of {noun}s");
    Ok(sweep)
}

/// Parse `--topology NAME`, validating it against the zoo registry so a
/// typo errors out before any sweep runs.  Bench configs carry the raw
/// name; the canonical label lands in the JSON artifacts downstream.
fn parse_topology(args: &Args) -> anyhow::Result<Option<String>> {
    match args.flag("topology") {
        None => Ok(None),
        Some(name) => {
            zoo::by_name(name)?;
            Ok(Some(name.to_string()))
        }
    }
}

/// Parse `--threads N` (default 1): worker threads handed to the
/// component-parallel DES engine (DESIGN.md section 14).
fn parse_threads(args: &Args) -> anyhow::Result<usize> {
    let n = args.get_parsed::<usize>("threads")?.unwrap_or(1);
    anyhow::ensure!(n >= 1, "--threads must be at least 1");
    Ok(n)
}

/// Parse a `--threads T1,T2,..` comma list — the scale bench's threads
/// axis (default just 1, the bit-identical serial engine).
fn parse_threads_list(args: &Args) -> anyhow::Result<Vec<usize>> {
    let list: Vec<usize> = match args.flag("threads") {
        Some(s) => s
            .split(',')
            .map(|w| {
                let w = w.trim();
                w.parse()
                    .map_err(|_| anyhow::anyhow!("--threads: invalid thread count {w:?}"))
            })
            .collect::<anyhow::Result<_>>()?,
        None => vec![1],
    };
    anyhow::ensure!(!list.is_empty(), "--threads needs a comma-separated list of counts");
    anyhow::ensure!(list.iter().all(|&t| t >= 1), "--threads counts must be at least 1");
    Ok(list)
}

fn cmd_bench_scale(args: &Args, csv: bool, seed: u64) -> anyhow::Result<()> {
    let defaults = bench::ScaleConfig::default();
    let sweep = parse_sweep(args, "flow count", &defaults.sweep)?;
    let cfg = bench::ScaleConfig {
        sweep,
        seed,
        baseline_max: args.get_usize("baseline-max", defaults.baseline_max),
        topology: parse_topology(args)?,
        threads: parse_threads_list(args)?,
    };
    let events_before = deeper::sim::events_total();
    let t0 = std::time::Instant::now();
    let (exhibits, json) = bench::scale_report(&cfg);
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    let events = deeper::sim::events_total() - events_before;
    for e in exhibits {
        println!("{}", if csv { e.render_csv() } else { e.render() });
    }
    if csv {
        // Per-worker event counters of the largest sweep point's highest
        // thread count, straight from the artifact (missing pieces — e.g.
        // a pure-serial run — degrade to no suffix).
        let workers = json
            .get("points")
            .and_then(Json::as_arr)
            .and_then(<[Json]>::last)
            .and_then(|p| p.get("runs"))
            .and_then(Json::as_arr)
            .and_then(<[Json]>::last)
            .and_then(|r| r.get("worker_events"))
            .and_then(Json::as_arr)
            .map(|w| {
                w.iter()
                    .filter_map(Json::as_f64)
                    .map(|n| format!("{}", n as u64))
                    .collect::<Vec<_>>()
                    .join("/")
            })
            .filter(|w| !w.is_empty())
            .map(|w| format!(", worker events {w}"))
            .unwrap_or_default();
        println!(
            "# engine: {events} events, {:.3e} events/s{workers}",
            events as f64 / wall
        );
    }
    let path = args.get_str("json", "BENCH_sim_scale.json");
    std::fs::write(path, json.to_pretty_string())
        .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
    println!("{}wrote {path}", if csv { "# " } else { "" });
    Ok(())
}

fn cmd_bench_fleet(args: &Args, csv: bool, seed: u64) -> anyhow::Result<()> {
    let defaults = bench::FleetBenchConfig::default();
    let sweep = parse_sweep(args, "job count", &defaults.sweep)?;
    let cfg = bench::FleetBenchConfig {
        sweep,
        seed,
        mtbf_node: args.get_parsed::<f64>("mtbf")?,
        topology: parse_topology(args)?,
    };
    let (exhibits, json) = bench::fleet_report(&cfg);
    for e in exhibits {
        println!("{}", if csv { e.render_csv() } else { e.render() });
    }
    let path = args.get_str("json", "BENCH_fleet.json");
    std::fs::write(path, json.to_pretty_string())
        .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
    println!("{}wrote {path}", if csv { "# " } else { "" });
    Ok(())
}

fn cmd_bench_resilience(args: &Args, csv: bool, seed: u64) -> anyhow::Result<()> {
    let defaults = bench::ResilienceBenchConfig::default();
    let cfg = bench::ResilienceBenchConfig {
        jobs: args.get_parsed::<usize>("jobs")?.unwrap_or(defaults.jobs),
        faults: args.get_parsed::<usize>("faults")?.unwrap_or(defaults.faults),
        seed,
        topology: parse_topology(args)?,
    };
    anyhow::ensure!(cfg.jobs > 0, "--jobs must be positive");
    anyhow::ensure!(cfg.faults > 0, "--faults must be positive");
    let (exhibits, json) = bench::resilience_report(&cfg);
    for e in exhibits {
        println!("{}", if csv { e.render_csv() } else { e.render() });
    }
    let path = args.get_str("json", "BENCH_resilience.json");
    std::fs::write(path, json.to_pretty_string())
        .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
    println!("{}wrote {path}", if csv { "# " } else { "" });
    Ok(())
}

fn cmd_bench_serve(args: &Args, csv: bool, seed: u64) -> anyhow::Result<()> {
    let defaults = bench::ServeBenchConfig::default();
    let cfg = bench::ServeBenchConfig {
        jobs: args.get_parsed::<usize>("jobs")?.unwrap_or(defaults.jobs),
        rate_hz: args.get_parsed::<f64>("rate")?.unwrap_or(defaults.rate_hz),
        queue_cap: args.get_parsed::<usize>("queue-cap")?.unwrap_or(defaults.queue_cap),
        seed,
        topology: parse_topology(args)?,
    };
    anyhow::ensure!(cfg.jobs > 0, "--jobs must be positive");
    anyhow::ensure!(
        cfg.rate_hz.is_finite() && cfg.rate_hz > 0.0,
        "--rate must be positive"
    );
    anyhow::ensure!(cfg.queue_cap > 0, "--queue-cap must be positive");
    let (exhibits, json) = bench::serve_report(&cfg);
    for e in exhibits {
        println!("{}", if csv { e.render_csv() } else { e.render() });
    }
    let path = args.get_str("json", "BENCH_serve.json");
    std::fs::write(path, json.to_pretty_string())
        .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
    println!("{}wrote {path}", if csv { "# " } else { "" });
    Ok(())
}

fn cmd_bench_qos(args: &Args, csv: bool, seed: u64) -> anyhow::Result<()> {
    let defaults = bench::QosBenchConfig::default();
    let cfg = bench::QosBenchConfig {
        // Strict parse: a typo'd --iters must error, not silently write
        // a default-configuration BENCH_qos.json.
        iterations: args.get_parsed::<usize>("iters")?.unwrap_or(defaults.iterations),
        seed,
        topology: parse_topology(args)?,
        threads: parse_threads(args)?,
        ..defaults
    };
    anyhow::ensure!(cfg.iterations > 0, "--iters must be positive");
    let (exhibits, json) = bench::qos_report(&cfg);
    for e in exhibits {
        println!("{}", if csv { e.render_csv() } else { e.render() });
    }
    let path = args.get_str("json", "BENCH_qos.json");
    std::fs::write(path, json.to_pretty_string())
        .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
    println!("{}wrote {path}", if csv { "# " } else { "" });
    Ok(())
}

fn cmd_bench_obs(args: &Args, csv: bool, seed: u64) -> anyhow::Result<()> {
    let defaults = bench::ObsBenchConfig::default();
    let cfg = bench::ObsBenchConfig {
        jobs: args.get_parsed::<usize>("jobs")?.unwrap_or(defaults.jobs),
        seed,
        repeats: args.get_parsed::<usize>("repeats")?.unwrap_or(defaults.repeats),
        span_cap: args.get_parsed::<usize>("span-cap")?.unwrap_or(defaults.span_cap),
    };
    anyhow::ensure!(cfg.jobs > 0, "--jobs must be positive");
    anyhow::ensure!(cfg.repeats > 0, "--repeats must be positive");
    anyhow::ensure!(cfg.span_cap > 0, "--span-cap must be positive");
    let (exhibits, json) = bench::obs_report(&cfg);
    for e in exhibits {
        println!("{}", if csv { e.render_csv() } else { e.render() });
    }
    let path = args.get_str("json", "BENCH_obs.json");
    std::fs::write(path, json.to_pretty_string())
        .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
    println!("{}wrote {path}", if csv { "# " } else { "" });
    Ok(())
}

/// Write a recorded trace as the Chrome trace-event artifact of
/// `--trace-out` (shared by `repro run`/`fleet`/`serve`).
fn write_trace(path: &str, tr: &obs::Trace) -> anyhow::Result<()> {
    std::fs::write(path, tr.chrome_trace().to_pretty_string())
        .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
    let dropped = match tr.dropped() {
        0 => String::new(),
        d => format!(", oldest {d} dropped at ring cap"),
    };
    println!("wrote {path} ({} span events{dropped})", tr.span_count());
    Ok(())
}

fn cmd_bench(args: &Args) -> anyhow::Result<()> {
    let name = args
        .positionals
        .first()
        .map(String::as_str)
        .unwrap_or("all");
    let csv = args.has("csv");
    let seed = args.get_u64("seed", bench::DEFAULT_SEED);
    if name == "scale" {
        return cmd_bench_scale(args, csv, seed);
    }
    if name == "fleet" {
        return cmd_bench_fleet(args, csv, seed);
    }
    if name == "qos" {
        return cmd_bench_qos(args, csv, seed);
    }
    if name == "serve" {
        return cmd_bench_serve(args, csv, seed);
    }
    if name == "resilience" {
        return cmd_bench_resilience(args, csv, seed);
    }
    if name == "obs" {
        return cmd_bench_obs(args, csv, seed);
    }
    if name == "all" {
        for n in bench::names() {
            println!("--- {n} ---");
            print_exhibits(n, csv, seed).expect("names() entries resolve");
        }
        return Ok(());
    }
    print_exhibits(name, csv, seed).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown exhibit {name}; try fig3..fig10, fig8-async, table1..table3, cb-split, scale, fleet, serve, qos, resilience, obs, all"
        )
    })?;
    Ok(())
}

fn cmd_fleet(args: &Args) -> anyhow::Result<()> {
    let n = args.get_parsed::<usize>("jobs")?.unwrap_or(8);
    anyhow::ensure!(n > 0, "--jobs must be positive");
    let policy = Policy::parse(args.get_str("policy", "fcfs"))?;
    let seed = args.get_u64("seed", bench::DEFAULT_SEED);
    let mtbf = args.get_parsed::<f64>("mtbf")?;
    let qos = args.has("qos");
    let resilience = sched::ResiliencePolicy::parse(args.get_str("resilience", "reactive"))?;
    let threads = parse_threads(args)?;
    let topo = parse_topology(args)?;
    let mspec = || -> anyhow::Result<deeper::system::MachineSpec> {
        Ok(match &topo {
            Some(name) => zoo::by_name(name)?,
            None => presets::deep_er(),
        })
    };
    let mk_cfg = |fault_plan| FleetConfig {
        policy,
        seed,
        mtbf_node: mtbf,
        qos,
        threads,
        fault_plan,
        resilience,
        ..FleetConfig::default()
    };
    // --faults: a fault-free probe run sizes the correlated schedule's
    // horizon so the degradation windows land inside the fleet's actual
    // runtime (mirrors `repro bench resilience`).
    let fault_plan = match args.get_parsed::<usize>("faults")? {
        Some(k) => {
            anyhow::ensure!(k > 0, "--faults must be positive");
            let spec = mspec()?;
            let nodes = spec.n_cluster + spec.n_booster;
            let probe = sched::run_fleet_on(spec, sched::synthetic_jobs(n, seed), mk_cfg(None))?;
            Some(FaultPlan::correlated(nodes, k, probe.makespan * 0.8, seed))
        }
        None => None,
    };
    // --trace-out: record the measured run (never the sizing probe)
    // and export it as Chrome trace-event JSON after the report.
    let trace_out = args.flag("trace-out");
    let trace = trace_out.map(|_| obs::Trace::new());
    let mut cfg = mk_cfg(fault_plan);
    cfg.trace = trace.clone();
    let report = sched::run_fleet_on(mspec()?, sched::synthetic_jobs(n, seed), cfg)?;

    println!(
        "fleet         : {} jobs, policy {}, topology {}, seed {seed}{}{}",
        report.jobs.len(),
        report.policy.name(),
        report.topology,
        match report.mtbf_node {
            Some(m) => format!(", per-node MTBF {m} s"),
            None => ", no failure injection".into(),
        },
        if report.qos { ", qos admission on" } else { "" }
    );
    println!(
        "{:<22} {:>5} {:>5} {:>4} {:>9} {:>9} {:>9} {:>5} {:>4} {:>7}",
        "job", "nodes", "prio", "iter", "start", "end", "wait", "fail", "rq", "cp-ovh"
    );
    for j in &report.jobs {
        println!(
            "{:<22} {:>2}c+{:>1}b {:>5} {:>4} {:>9} {:>9} {:>9} {:>5} {:>4} {:>6.1}%",
            j.name,
            j.cluster,
            j.booster,
            j.priority,
            j.iterations,
            fmt_time(j.first_start),
            fmt_time(j.finished_at),
            fmt_time(j.wait_time),
            j.stats.failures_hit,
            j.requeues,
            j.stats.ckpt_overhead() * 100.0
        );
    }
    println!("makespan      : {}", fmt_time(report.makespan));
    println!("utilization   : {:.1} %", report.utilization * 100.0);
    println!("avg wait      : {}", fmt_time(report.avg_wait));
    println!(
        "failures      : {} on jobs, {} on idle nodes",
        report.failures_injected, report.idle_failures
    );
    println!("cancelled     : {} in-flight flows at kill time", report.flows_cancelled);
    if let Some(rs) = &report.resilience {
        println!(
            "resilience    : {} policy, {} migrations, {} wasted iterations, {} suspects",
            rs.policy, rs.migrations, rs.wasted_iterations, rs.suspects
        );
        println!(
            "faults applied: {} link degrades, {} stragglers, {} corruptions",
            rs.link_degrades, rs.stragglers, rs.corruptions
        );
    }
    println!("finish order  : {:?}", report.finish_order);
    println!("sim events    : {}", report.sim_events);
    if let Some(path) = args.flag("json") {
        std::fs::write(path, report.to_json().to_pretty_string())
            .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
        println!("wrote {path}");
    }
    if let (Some(path), Some(tr)) = (trace_out, &trace) {
        write_trace(path, tr)?;
    }
    Ok(())
}

/// Parse `--arrivals poisson|trace` (with `--rate` / `--trace PATH`)
/// into the service loop's arrival process.  A trace file holds one
/// arrival offset in seconds per line; blank lines and `#` comments are
/// skipped, and `sched::serve` validates ordering.
fn parse_arrivals(args: &Args) -> anyhow::Result<sched::ArrivalSpec> {
    match args.get_str("arrivals", "poisson") {
        "poisson" => {
            let rate_hz = args.get_parsed::<f64>("rate")?.unwrap_or(1.0);
            anyhow::ensure!(
                rate_hz.is_finite() && rate_hz > 0.0,
                "--rate must be positive"
            );
            Ok(sched::ArrivalSpec::Poisson { rate_hz })
        }
        "trace" => {
            let path = args
                .flag("trace")
                .ok_or_else(|| anyhow::anyhow!("--arrivals trace needs --trace PATH"))?;
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
            let times = text
                .lines()
                .map(str::trim)
                .filter(|l| !l.is_empty() && !l.starts_with('#'))
                .map(|l| {
                    l.parse::<f64>()
                        .map_err(|_| anyhow::anyhow!("{path}: bad arrival offset {l:?}"))
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            Ok(sched::ArrivalSpec::Trace { times })
        }
        other => anyhow::bail!("unknown arrival process {other}; try poisson or trace"),
    }
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let defaults = sched::ServeConfig::default();
    let jobs = args.get_parsed::<usize>("jobs")?.unwrap_or(defaults.jobs);
    anyhow::ensure!(jobs > 0, "--jobs must be positive");
    let seed = args.get_u64("seed", bench::DEFAULT_SEED);
    let arrivals = parse_arrivals(args)?;
    let policy = Policy::parse(args.get_str("policy", "backfill"))?;
    let queue_cap = args.get_parsed::<usize>("queue-cap")?.unwrap_or(defaults.queue_cap);
    let window_s = args.get_parsed::<f64>("window")?.unwrap_or(defaults.window_s);
    let reserve_depth = args
        .get_parsed::<usize>("reserve-depth")?
        .unwrap_or(defaults.fleet.reserve_depth);
    anyhow::ensure!(reserve_depth > 0, "--reserve-depth must be positive");
    let qos = args.has("qos");
    let threads = parse_threads(args)?;
    let mspec = match parse_topology(args)? {
        Some(name) => zoo::by_name(&name)?,
        None => presets::deep_er(),
    };
    // --faults: the correlated schedule's horizon comes from the arrival
    // process itself (expected Poisson horizon, or the last trace
    // offset) — open-arrival mode needs no probe run.
    let fault_plan = match args.get_parsed::<usize>("faults")? {
        Some(k) => {
            anyhow::ensure!(k > 0, "--faults must be positive");
            let horizon = match &arrivals {
                sched::ArrivalSpec::Poisson { rate_hz } => jobs as f64 / rate_hz,
                sched::ArrivalSpec::Trace { times } => times.last().copied().unwrap_or(0.0),
            };
            anyhow::ensure!(horizon > 0.0, "--faults needs a positive arrival horizon");
            let nodes = mspec.n_cluster + mspec.n_booster;
            Some(FaultPlan::correlated(nodes, k, horizon, seed))
        }
        None => None,
    };
    let trace_out = args.flag("trace-out");
    let trace = trace_out.map(|_| obs::Trace::new());
    let scfg = sched::ServeConfig {
        fleet: FleetConfig {
            policy,
            seed,
            qos,
            threads,
            fault_plan,
            reserve_depth,
            trace: trace.clone(),
            ..defaults.fleet.clone()
        },
        arrivals,
        jobs,
        queue_cap,
        window_s,
        ..defaults
    };
    let r = sched::serve_fleet_on(mspec, scfg)?;

    println!(
        "serve         : {} arrivals ({}{}), policy {}, topology {}, seed {seed}{}",
        r.jobs_arrived,
        r.arrivals,
        match r.rate_hz {
            Some(rate) => format!(" at {rate} Hz"),
            None => String::new(),
        },
        r.policy.name(),
        r.topology,
        if r.qos { ", qos admission on" } else { "" }
    );
    println!(
        "admission     : {} admitted, {} rejected ({:.2} %) at queue cap {}",
        r.jobs_admitted,
        r.jobs_rejected,
        r.rejection_rate * 100.0,
        r.queue_cap
    );
    println!(
        "drain         : {} completed, horizon {}, makespan {}",
        r.jobs_completed,
        fmt_time(r.horizon_s),
        fmt_time(r.makespan_s)
    );
    println!("utilization   : {:.1} %", r.utilization * 100.0);
    println!("avg wait      : {}", fmt_time(r.avg_wait_s));
    for c in &r.classes {
        println!(
            "class {} wait  : p50 {}, p99 {}, max {} ({} completed, {} rejected)",
            c.class,
            fmt_time(c.p50_wait_s),
            fmt_time(c.p99_wait_s),
            fmt_time(c.max_wait_s),
            c.completed,
            c.rejected
        );
    }
    println!(
        "failures      : {} on jobs, {} on idle nodes, {} requeues, {} migrations",
        r.failures_injected, r.idle_failures, r.requeues, r.migrations
    );
    println!("qos grants    : {} still open after drain", r.qos_grants_open);
    println!(
        "windows       : {} x {} s (merged), sim events {}",
        r.windows.len(),
        r.window_s,
        r.sim_events
    );
    if let Some(path) = args.flag("json") {
        std::fs::write(path, r.to_json().to_pretty_string())
            .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
        println!("wrote {path}");
    }
    if let (Some(path), Some(tr)) = (trace_out, &trace) {
        write_trace(path, tr)?;
    }
    Ok(())
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let profile = match args.get_str("app", "xpic") {
        "nbody" => apps::nbody::profile(),
        "xpic" => apps::xpic::profile_deep_er(),
        "gershwin" => apps::gershwin::profile_p1(),
        "fwi" => apps::fwi::profile(),
        other => anyhow::bail!("unknown app {other}"),
    };
    let strat = parse_strategy(args.get_str("strategy", "buddy"))?;
    let iterations = args.get_usize("iterations", 100);
    let cp_interval = args.get_usize("cp-interval", 10);
    let nodes = args.get_usize("nodes", 16);
    let seed = args.get_u64("seed", bench::DEFAULT_SEED);
    let multilevel = args.has("multilevel") || args.has("async-flush");

    let mspec = match parse_topology(args)? {
        Some(name) => zoo::by_name(&name)?,
        None => presets::deep_er(),
    };
    let mut m = Machine::build(mspec);
    m.sim.set_threads(parse_threads(args)?);
    // --trace-out: solo runs trace as pid 1 (there is no scheduler, so
    // scr/flush/io spans land on the job process; engine events on pid 0).
    let trace_out = args.flag("trace-out");
    let trace = trace_out.map(|_| obs::Trace::new());
    if let Some(tr) = &trace {
        m.sim.set_trace(tr.clone());
        let _ = m.sim.set_trace_pid(1);
        tr.set_process_name(0, "system");
        tr.set_thread_name(0, obs::lane::MAIN, "sched");
        tr.set_thread_name(0, obs::lane::ENGINE, "engine");
        tr.set_process_name(1, format!("run {}", profile.name));
        tr.set_thread_name(1, obs::lane::MAIN, "phase");
        tr.set_thread_name(1, obs::lane::SCR, "scr");
        tr.set_thread_name(1, obs::lane::FLUSH, "flush");
        tr.set_thread_name(1, obs::lane::IO, "io");
    }
    let node_ids: Vec<usize> = m.nodes_of(NodeKind::Cluster).into_iter().take(nodes).collect();
    // Failure plan: a targeted --fail-at iteration wins; otherwise --mtbf
    // samples an exponential schedule reproducible from --seed.
    let failures = if let Some(i) = args.flag("fail-at").and_then(|v| v.parse::<usize>().ok()) {
        FailurePlan::one_at_iteration(0, i)
    } else if let Some(mtbf) = args.flag("mtbf").and_then(|v| v.parse::<f64>().ok()) {
        FailurePlan::exponential(node_ids.len(), mtbf, 1e7, seed)
    } else {
        FailurePlan::none()
    };
    let job = IterationJob { profile: profile.clone(), iterations, cp_interval, failures };

    let stats: RunStats = if multilevel {
        let cfg = MultiLevelConfig {
            l1_every: 1,
            l2_every: args.get_usize("l2-every", 2),
            l3_every: args.get_usize("l3-every", 2),
            l2_strategy: strat,
            async_flush: args.has("async-flush"),
        };
        let mut ml = MultiLevelScr::new(cfg);
        let stats = run_iterations_multilevel(&mut m, &node_ids, &job, &mut ml);
        println!(
            "flush         : {} L2 promotions ({} aborted), {} L3 flushes",
            ml.stats.l2_count, ml.stats.flush_aborted, ml.stats.l3_count
        );
        stats
    } else {
        let mut scr = Scr::new(strat);
        run_iterations(&mut m, &node_ids, &job, Some(&mut scr))
    };

    println!("app           : {}", profile.name);
    println!(
        "strategy      : {}{}",
        strat.name(),
        if multilevel {
            if args.has("async-flush") {
                " (multilevel, async flush)"
            } else {
                " (multilevel, blocking flush)"
            }
        } else {
            ""
        }
    );
    println!("nodes         : {}", node_ids.len());
    println!("topology      : {}", m.spec.topology.label());
    println!("threads       : {}", m.sim.threads());
    println!("seed          : {seed}");
    println!("iterations    : {} (run {})", iterations, stats.iterations_run);
    println!("total time    : {}", fmt_time(stats.total_time));
    println!("compute time  : {}", fmt_time(stats.compute_time));
    println!("exchange time : {}", fmt_time(stats.exchange_time));
    println!(
        "ckpt time     : {} ({} checkpoints, {:.1}% overhead)",
        fmt_time(stats.ckpt_time),
        stats.checkpoints_taken,
        stats.ckpt_overhead() * 100.0
    );
    println!("blocked time  : {}", fmt_time(stats.blocked_time));
    println!("overlap time  : {}", fmt_time(stats.overlap_time));
    println!(
        "restart time  : {} ({} failures)",
        fmt_time(stats.restart_time),
        stats.failures_hit
    );
    if let (Some(path), Some(tr)) = (trace_out, &trace) {
        write_trace(path, tr)?;
    }
    Ok(())
}

fn cmd_e2e(args: &Args) -> anyhow::Result<()> {
    let dir = args
        .flag("artifacts")
        .map(Into::into)
        .unwrap_or_else(default_artifacts_dir);
    let mut rt = Runtime::open(&dir)?;
    println!("artifacts: {:?}", rt.artifact_names());
    for name in rt.artifact_names() {
        let spec = rt.spec(&name).unwrap().clone();
        let inputs: Vec<Tensor> = spec
            .inputs
            .iter()
            .map(|s| match s.dtype.as_str() {
                "i32" => Tensor::I32 { shape: s.shape.clone(), data: vec![1; s.elements()] },
                _ => Tensor::F32 {
                    shape: s.shape.clone(),
                    data: (0..s.elements()).map(|i| (i % 97) as f32 * 1e-3).collect(),
                },
            })
            .collect();
        let t0 = std::time::Instant::now();
        let out = rt.execute(&name, &inputs)?;
        println!(
            "  {name}: {} outputs in {:.1} ms (first output: {} elems)",
            out.len(),
            t0.elapsed().as_secs_f64() * 1e3,
            out[0].len()
        );
    }
    println!("e2e smoke OK — python never ran on this path");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    match args.subcommand.as_deref() {
        Some("show-config") => {
            for ex in bench::table1() {
                println!("{}", ex.render());
            }
            println!("Other presets: QPACE3 (672x KNL, Fig. 6), MareNostrum 3 (Fig. 10)");
            Ok(())
        }
        Some("bench") => cmd_bench(&args),
        Some("split") => {
            use deeper::apps::split::{run_split, Placement, SplitJob};
            let iters = args.get_usize("iterations", 10);
            for placement in Placement::ALL {
                let mut m = Machine::build(presets::deep_er());
                let stats = run_split(&mut m, &SplitJob::xpic_like(iters), placement);
                println!(
                    "{:<24} total {:>7.1} s  (particle {:>6.1}, field {:>6.1}, coupling {:>5.2}, spawn {:>4.2})",
                    placement.name(),
                    stats.total_time,
                    stats.particle_time,
                    stats.field_time,
                    stats.coupling_time,
                    stats.spawn_time
                );
            }
            Ok(())
        }
        Some("run") => cmd_run(&args),
        Some("fleet") => cmd_fleet(&args),
        Some("serve") => cmd_serve(&args),
        Some("e2e") => cmd_e2e(&args),
        Some(other) => {
            eprintln!("unknown subcommand {other}\n{USAGE}");
            std::process::exit(2);
        }
        None => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

//! I/O-path ablations — the design choices DESIGN.md calls out:
//! record-size sensitivity of SIONlib's win, BeeOND sync vs async mode,
//! MDS service-time sensitivity (what the single-create collective open
//! is actually worth), and stripe-width scaling of the global FS.
//!
//!     cargo bench --bench bench_io

use deeper::beegfs::beeond::CacheDevice;
use deeper::beegfs::{BeeOnd, CacheMode};
use deeper::microbench::{black_box, Bench};
use deeper::sionlib::{write_sionlib, write_task_local, TaskLocalWorkload};
use deeper::system::{presets, Machine};

fn main() {
    // -- ablation: record size vs SIONlib speedup ------------------------
    println!("-- ablation: SIONlib speedup vs record size (8 nodes x 48 tasks, 8 MB/task) --");
    for records in [1u64, 8, 32, 96, 512] {
        let w = TaskLocalWorkload {
            nodes: 8,
            tasks_per_node: 48,
            bytes_per_task: 8e6,
            records_per_task: records,
        };
        let mut m1 = Machine::build(presets::deep_er());
        let base = write_task_local(&mut m1, &w);
        let mut m2 = Machine::build(presets::deep_er());
        let sion = write_sionlib(&mut m2, &w);
        println!(
            "  {:>7.0} KB records: task-local {:>7.2} s, sionlib {:>6.2} s, speedup {:>5.2}x",
            8e6 / records as f64 / 1e3,
            base.write_time,
            sion.write_time,
            base.write_time / sion.write_time
        );
    }

    // -- ablation: BeeOND sync vs async ----------------------------------
    println!("\n-- ablation: BeeOND cache mode (4 GB from one node) --");
    for (label, mode) in [("sync", CacheMode::Sync), ("async", CacheMode::Async)] {
        let mut m = Machine::build(presets::deep_er());
        let mut cache = BeeOnd::new(CacheDevice::Nvme, mode);
        let t0 = m.sim.now();
        let visible = cache.write(&mut m, 0, 4e9, 4) - t0;
        let durable = cache.drain(&mut m) - t0;
        println!("  {label:>5}: visible {visible:>5.2} s, globally durable {durable:>5.2} s");
    }

    // -- ablation: MDS service time --------------------------------------
    println!("\n-- ablation: MDS op cost vs task-local write time (8 nodes) --");
    for mds_ms in [0.2f64, 0.8, 3.2] {
        let mut spec = presets::deep_er();
        spec.mds_op_cost = mds_ms * 1e-3;
        let mut m = Machine::build(spec);
        let w = TaskLocalWorkload {
            nodes: 8,
            tasks_per_node: 48,
            bytes_per_task: 4e6,
            records_per_task: 96,
        };
        let base = write_task_local(&mut m, &w);
        println!("  mds={mds_ms:.1} ms: task-local {:.2} s", base.write_time);
    }

    // -- ablation: storage-server count (stripe width) -------------------
    println!("\n-- ablation: OSS count vs 16-node aggregate write --");
    for servers in [1usize, 2, 4, 8] {
        let mut spec = presets::deep_er();
        spec.n_storage_servers = servers;
        let mut m = Machine::build(spec);
        let nodes: Vec<usize> = (0..16).collect();
        let t = deeper::beegfs::beeond::concurrent_global_write(&mut m, &nodes, 1e9);
        println!(
            "  {servers} OSS: {t:>6.2} s  ({:.2} GB/s aggregate)",
            16.0 / t
        );
    }

    // -- host-time micro: the I/O model itself ---------------------------
    let b = Bench::quick("io_model");
    b.run("sionlib_write_8x48", || {
        let mut m = Machine::build(presets::deep_er());
        let w = TaskLocalWorkload {
            nodes: 8,
            tasks_per_node: 48,
            bytes_per_task: 4e6,
            records_per_task: 96,
        };
        black_box(write_sionlib(&mut m, &w));
    });
    b.run("task_local_write_8x48", || {
        let mut m = Machine::build(presets::deep_er());
        let w = TaskLocalWorkload {
            nodes: 8,
            tasks_per_node: 48,
            bytes_per_task: 4e6,
            records_per_task: 96,
        };
        black_box(write_task_local(&mut m, &w));
    });
}

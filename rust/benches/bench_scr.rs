//! Benchmarks of the SCR strategy write paths and the OmpSs executor —
//! one bench per paper-evaluation component, plus the ablations DESIGN.md
//! calls out (XOR group size, NAM board count, payload scaling).
//!
//!     cargo bench --bench bench_scr

use deeper::microbench::{black_box, Bench};
use deeper::ompss::{OmpssRuntime, Resilience};
use deeper::scr::{Scr, Strategy};
use deeper::system::failure::FailurePlan;
use deeper::system::{presets, Machine, NodeKind};

fn ckpt(strategy: Strategy, bytes: f64, group: usize) -> f64 {
    let mut m = Machine::build(presets::deep_er());
    let nodes = m.nodes_of(NodeKind::Cluster);
    let mut scr = Scr::new(strategy).with_group(group);
    scr.checkpoint(&mut m, &nodes, bytes).unwrap().blocked
}

fn main() {
    let b = Bench::quick("scr");
    for strat in Strategy::ALL {
        b.run(strat.name(), || {
            black_box(ckpt(strat, 2e9, 4));
        });
    }

    // Ablation: XOR group size (storage vs time trade-off of DistXor).
    println!("\n-- ablation: DistXor group size (2 GB/node, 16 nodes) --");
    for group in [2usize, 4, 8, 16] {
        let t = ckpt(Strategy::DistXor, 2e9, group);
        let parity = 2e9 / (group as f64 - 1.0);
        println!(
            "  group={group:>2}: ckpt {t:.2} s virtual, parity/node {:.0} MB",
            parity / 1e6
        );
    }

    // Ablation: NAM board count (pull bandwidth aggregation).
    println!("\n-- ablation: NAM board count (2 GB/node, 16 nodes) --");
    for boards in [1usize, 2, 4] {
        let mut spec = presets::deep_er();
        spec.n_nam = boards;
        let mut m = Machine::build(spec);
        let nodes = m.nodes_of(NodeKind::Cluster);
        let mut scr = Scr::new(Strategy::NamXor);
        let r = scr.checkpoint(&mut m, &nodes, 2e9).unwrap();
        println!(
            "  boards={boards}: ckpt {:.2} s virtual, {:.1} GB/s",
            r.blocked,
            r.bandwidth / 1e9
        );
    }

    // Ablation: payload scaling (Buddy).
    println!("\n-- ablation: Buddy payload scaling --");
    for gb in [1.0f64, 2.0, 4.0, 8.0] {
        let t = ckpt(Strategy::Buddy, gb * 1e9, 4);
        println!("  {gb:>4.0} GB/node: {t:.2} s virtual");
    }

    // OmpSs executor throughput (host-time cost of the task engine).
    let graph = deeper::apps::fwi::task_graph(5, 4, 3e11);
    let b2 = Bench::quick("ompss");
    b2.run("fwi_5x4_clean", || {
        let mut m = Machine::build(presets::marenostrum3());
        let rt = OmpssRuntime::new(0, Resilience::ResilientOffload);
        black_box(rt.execute(&mut m, &graph, &[1, 2, 3, 4], &FailurePlan::none()));
    });
    b2.run("fwi_5x4_with_failure", || {
        let mut m = Machine::build(presets::marenostrum3());
        let rt = OmpssRuntime::new(0, Resilience::ResilientOffload);
        let fail = FailurePlan::one_at_iteration(0, deeper::apps::fwi::last_task(&graph));
        black_box(rt.execute(&mut m, &graph, &[1, 2, 3, 4], &fail));
    });
}

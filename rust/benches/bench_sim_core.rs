//! Micro-benchmarks of the DES engine hot path (the L3 profile target of
//! DESIGN.md section 8: >= 1e6 events/s through the fluid scheduler).
//!
//!     cargo bench --bench bench_sim_core

use deeper::microbench::{black_box, Bench};
use deeper::sim::Sim;

/// N flows on one shared link: stresses recompute_rates' tie-batching.
fn shared_link(n: usize) {
    let mut sim = Sim::new();
    let link = sim.resource("l", 12.5e9);
    let flows: Vec<_> = (0..n)
        .map(|i| sim.flow(1e6 * (1 + i % 7) as f64, 1e-6 * (i % 3) as f64, &[link]))
        .collect();
    black_box(sim.wait_all(&flows));
}

/// N flows on N independent devices: the 672-node Fig. 6 local pattern —
/// all bottlenecks tie at the same share and must batch-fix in one pass.
fn independent_devices(n: usize) {
    let mut sim = Sim::new();
    let flows: Vec<_> = (0..n)
        .map(|i| {
            let dev = sim.resource(format!("d{i}"), 1.9e9);
            sim.flow(1e9, 0.0, &[dev])
        })
        .collect();
    black_box(sim.wait_all(&flows));
}

/// Incast: N senders through private NICs into one shared backend.
fn incast(n: usize) {
    let mut sim = Sim::new();
    let backend = sim.resource("srv", 2.4e9);
    let flows: Vec<_> = (0..n)
        .map(|i| {
            let nic = sim.resource(format!("nic{i}"), 12.5e9);
            sim.flow(1e8, 1e-6, &[nic, backend])
        })
        .collect();
    black_box(sim.wait_all(&flows));
}

/// Staggered arrivals force a rate recomputation per event.
fn staggered_events(n: usize) {
    let mut sim = Sim::new();
    let link = sim.resource("l", 1e9);
    let flows: Vec<_> = (0..n)
        .map(|i| sim.flow(1e7, 1e-4 * i as f64, &[link]))
        .collect();
    black_box(sim.wait_all(&flows));
}

/// Staggered arrivals over DISJOINT per-node devices: every event's
/// refill touches one single-flow component, never the other n-1 — the
/// pattern the component-scoped recompute exists for.
fn staggered_disjoint(n: usize) {
    let mut sim = Sim::new();
    let flows: Vec<_> = (0..n)
        .map(|i| {
            let dev = sim.resource("d", 1.9e9);
            sim.flow(1e9, 1e-4 * i as f64, &[dev])
        })
        .collect();
    black_box(sim.wait_all(&flows));
}

/// The same staggered shared-link workload on the naive reference engine
/// (per-event sweep + global refill) — the bench prints both so the gap
/// is visible next to the optimized numbers.
fn staggered_events_naive(n: usize) {
    let mut sim = deeper::sim::reference::RefSim::new();
    let link = sim.resource(1e9);
    let flows: Vec<_> = (0..n)
        .map(|i| sim.flow(1e7, 1e-4 * i as f64, &[link]))
        .collect();
    black_box(sim.wait_all(&flows));
}

fn main() {
    let b = Bench::new("sim_core");
    b.run("shared_link_16", || shared_link(16));
    b.run("shared_link_128", || shared_link(128));
    b.run("independent_devices_128", || independent_devices(128));
    b.run("independent_devices_672", || independent_devices(672));
    b.run("incast_64", || incast(64));
    b.run("staggered_disjoint_512", || staggered_disjoint(512));
    b.run("staggered_events_naive_512", || staggered_events_naive(512));
    let stats = b.run("staggered_events_512", || staggered_events(512));
    // Events/s: each flow is >= 2 events (start, finish).
    let eps = 1024.0 / stats.mean_s();
    println!("sim_core/staggered events/s: {eps:.3e}");

    let bq = Bench::quick("machine");
    bq.run("build_deep_er", || {
        black_box(deeper::system::Machine::build(deeper::system::presets::deep_er()));
    });
    bq.run("build_qpace3_672", || {
        black_box(deeper::system::Machine::build(deeper::system::presets::qpace3()));
    });
}

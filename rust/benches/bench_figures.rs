//! End-to-end benchmark: regenerate every paper exhibit and time the
//! harness (host time; the printed figures themselves are virtual time).
//! This is the `cargo bench` face of `repro bench all` — one bench per
//! paper table AND figure, as the deliverables require.
//!
//!     cargo bench --bench bench_figures

use deeper::bench as figs;
use deeper::microbench::{black_box, Bench};

fn main() {
    let b = Bench::quick("figures");
    b.run("table1", || {
        black_box(figs::table1());
    });
    b.run("table2", || {
        black_box(figs::table2());
    });
    b.run("table3", || {
        black_box(figs::table3());
    });
    b.run("fig3_nam_rma", || {
        black_box(figs::fig3());
    });
    b.run("fig4_nbody_ckpt_strategies", || {
        black_box(figs::fig4());
    });
    b.run("fig5_sionlib_gershwin", || {
        black_box(figs::fig5());
    });
    b.run("fig6_qpace3_beeond", || {
        black_box(figs::fig6());
    });
    b.run("fig7_nvme_vs_hdd", || {
        black_box(figs::fig7());
    });
    b.run("fig8_scr_partner", || {
        black_box(figs::fig8());
    });
    b.run("fig9_dist_vs_nam_xor", || {
        black_box(figs::fig9());
    });
    b.run("fig10_fwi_ompss", || {
        black_box(figs::fig10());
    });

    // Whole-suite timing (the `make figures` budget: target < 2 min).
    let b2 = Bench::quick("suite");
    let stats = b2.run("all_exhibits", || {
        black_box(figs::all(figs::DEFAULT_SEED));
    });
    println!(
        "suite/all_exhibits single pass: {:.2} s host time",
        stats.mean_s()
    );
}

"""Make `compile.*` importable regardless of pytest's invocation cwd
(the CI entry point runs `pytest python/tests/` from the repo root)."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

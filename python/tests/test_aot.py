"""AOT pipeline contracts: manifest consistency, HLO text parseability."""

import json
import pathlib

import pytest

from compile import aot, model

ART = pathlib.Path(__file__).resolve().parents[2] / "artifacts"


@pytest.fixture(scope="module")
def manifest():
    path = ART / "manifest.json"
    if not path.exists():
        pytest.skip("artifacts not built (run `make artifacts`)")
    return json.loads(path.read_text())


def test_manifest_covers_all_entry_points(manifest):
    names = {a["name"] for a in manifest["artifacts"]}
    expected = {name for name, _, _ in model.aot_entry_points()}
    assert names == expected


def test_manifest_format_is_hlo_text(manifest):
    assert manifest["format"] == "hlo-text"


def test_artifact_files_exist_and_look_like_hlo(manifest):
    for a in manifest["artifacts"]:
        path = ART / a["file"]
        assert path.exists(), a["file"]
        head = path.read_text()[:200]
        assert "HloModule" in head, f"{a['file']} does not look like HLO text"


def test_manifest_specs_match_entry_points(manifest):
    by_name = {a["name"]: a for a in manifest["artifacts"]}
    for name, _, example_args in model.aot_entry_points():
        entry = by_name[name]
        assert len(entry["inputs"]) == len(example_args), name
        for spec, arg in zip(entry["inputs"], example_args):
            assert spec["shape"] == list(arg.shape), name
            assert spec["dtype"] in ("f32", "i32"), name


def test_lower_all_roundtrip(tmp_path):
    """Re-lowering into a temp dir reproduces the same artifact set."""
    man = aot.lower_all(tmp_path)
    assert (tmp_path / "manifest.json").exists()
    for a in man["artifacts"]:
        assert (tmp_path / a["file"]).exists()
        assert len(a["outputs"]) >= 1

"""L1 correctness: tiled Pallas N-body forces vs the dense jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import nbody, ref


def _cloud(n, seed=0):
    kp, km = jax.random.split(jax.random.PRNGKey(seed))
    pos = jax.random.normal(kp, (n, 3), jnp.float32)
    mass = jnp.abs(jax.random.normal(km, (n,), jnp.float32)) + 0.1
    return pos, mass


def test_matches_ref_canonical():
    pos, mass = _cloud(512)
    got = nbody.nbody_forces(pos, mass)
    want = ref.nbody_forces_ref(pos, mass)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_single_tile():
    """N == tile size: grid of one."""
    pos, mass = _cloud(128)
    got = nbody.nbody_forces(pos, mass)
    want = ref.nbody_forces_ref(pos, mass)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_rejects_non_multiple():
    # 300 > TILE (so no clamping) and not a multiple of it.
    pos, mass = _cloud(300)
    with pytest.raises(ValueError, match="multiple"):
        nbody.nbody_forces(pos, mass)


def test_two_body_antisymmetry():
    """Equal masses: forces are equal and opposite (momentum conservation)."""
    pos = jnp.array([[0.0, 0.0, 0.0], [1.0, 0.0, 0.0]], jnp.float32)
    pos = jnp.tile(pos, (64, 1))  # pad to a tile multiple with pairs
    mass = jnp.ones((128,), jnp.float32)
    acc = nbody.nbody_forces(pos, mass)
    total = jnp.sum(acc * mass[:, None], axis=0)
    np.testing.assert_allclose(np.asarray(total), np.zeros(3), atol=1e-2)


def test_symmetric_cloud_zero_net_force():
    """Momentum conservation on a random cloud: sum_i m_i a_i == 0."""
    pos, mass = _cloud(256, seed=3)
    acc = nbody.nbody_forces(pos, mass)
    net = jnp.sum(acc * mass[:, None], axis=0)
    scale = jnp.sum(jnp.abs(acc * mass[:, None]))
    assert float(jnp.linalg.norm(net)) < 1e-4 * float(scale) + 1e-3


@settings(max_examples=10, deadline=None)
@given(
    n_tiles=st.integers(min_value=1, max_value=4),
    tile=st.sampled_from([64, 128]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_shapes(n_tiles, tile, seed):
    n = n_tiles * tile
    pos, mass = _cloud(n, seed=seed % 1000)
    got = nbody.nbody_forces(pos, mass, tile_i=tile, tile_j=tile)
    want = ref.nbody_forces_ref(pos, mass)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-4, atol=5e-4)

"""Perf-contract tests: VMEM budgets and HLO structure stay within the
bounds recorded in EXPERIMENTS.md §Perf (regression guard for the
tiling choices of the L1 optimization pass)."""

import pytest

from compile import roofline


def test_all_kernels_fit_vmem_budget():
    for name, vmem, _flops, _streamed, _notes in roofline.kernel_reports():
        assert vmem < roofline.VMEM_BUDGET, f"{name}: {vmem} bytes"


def test_nbody_is_compute_bound():
    reports = {r[0]: r for r in roofline.kernel_reports()}
    _, _, flops, streamed, _ = reports["nbody_forces"]
    ai = flops / streamed
    assert ai > 100.0, f"nbody arithmetic intensity regressed: {ai}"


def test_wave_is_memory_bound():
    reports = {r[0]: r for r in roofline.kernel_reports()}
    _, _, flops, streamed, _ = reports["wave_step"]
    ai = flops / streamed
    assert ai < 2.0, f"wave stencil AI should be memory-bound, got {ai}"


@pytest.mark.parametrize("name", ["nbody_step", "fwi_forward8", "nam_parity"])
def test_hlo_stays_compact(name):
    from compile import model

    entry = {n: (f, a) for n, f, a in model.aot_entry_points()}[name]
    st = roofline.hlo_stats(name, entry[0], entry[1])
    assert st["total_ops"] < 600, f"{name} HLO grew to {st['total_ops']} ops"
    # scan (fwi_forward8) is the only construct allowed to carry a while.
    if name != "fwi_forward8":
        assert st["while_loops"] <= 2


def test_no_gratuitous_copies():
    from compile import model

    for name, fn, args in model.aot_entry_points():
        st = roofline.hlo_stats(name, fn, args)
        assert st["copies"] <= 2, f"{name}: {st['copies']} copy ops"

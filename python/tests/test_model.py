"""L2 contracts: app step functions — shapes, dtypes, physics sanity."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model


def test_nbody_step_shapes():
    n = model.NBODY_N
    pos = jax.random.normal(jax.random.PRNGKey(0), (n, 3), jnp.float32)
    vel = jnp.zeros((n, 3), jnp.float32)
    mass = jnp.ones((n,), jnp.float32)
    p2, v2 = jax.jit(model.nbody_step)(pos, vel, mass)
    assert p2.shape == (n, 3) and v2.shape == (n, 3)
    assert p2.dtype == jnp.float32


def test_nbody_energy_drift_small():
    """Leapfrog on a small cloud: relative energy drift stays tiny over 20 steps."""
    n = 128
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    pos = jax.random.normal(ks[0], (n, 3), jnp.float32) * 2.0
    vel = jax.random.normal(ks[1], (n, 3), jnp.float32) * 0.05
    mass = jnp.full((n,), 1.0 / n, jnp.float32)
    e0 = float(model.nbody_energy(pos, vel, mass))
    step = jax.jit(model.nbody_step)
    for _ in range(20):
        pos, vel = step(pos, vel, mass)
    e1 = float(model.nbody_energy(pos, vel, mass))
    assert np.isfinite(e1)
    assert abs(e1 - e0) < 0.05 * abs(e0) + 1e-3


def test_xpic_step_contract():
    p, g = model.XPIC_P, model.XPIC_G
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    x = jax.random.uniform(ks[0], (p, 3), jnp.float32)
    v = jax.random.normal(ks[1], (p, 3), jnp.float32) * 0.01
    e = jax.random.normal(ks[2], (g**3, 3), jnp.float32) * 0.1
    b = jnp.zeros((g**3, 3), jnp.float32)
    x2, v2, e2, rho = jax.jit(model.xpic_step)(x, v, e, b)
    assert x2.shape == (p, 3) and v2.shape == (p, 3)
    assert e2.shape == (g**3, 3) and rho.shape == (g**3,)
    # Particles stay in the periodic box.
    xa = np.asarray(x2)
    assert (xa >= 0).all() and (xa < model.XPIC_L).all()
    # Charge conservation: every particle lands in exactly one cell.
    np.testing.assert_allclose(float(jnp.sum(rho)), p, rtol=1e-6)


def test_xpic_field_bounded():
    """Repeated steps with the damped field solver must not blow up."""
    p, g = 1024, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    x = jax.random.uniform(ks[0], (p, 3), jnp.float32)
    v = jax.random.normal(ks[1], (p, 3), jnp.float32) * 0.01
    e = jax.random.normal(ks[2], (g**3, 3), jnp.float32) * 0.1
    b = jnp.zeros((g**3, 3), jnp.float32)
    step = jax.jit(model.xpic_step)
    for _ in range(25):
        x, v, e, rho = step(x, v, e, b)
    assert np.isfinite(np.asarray(e)).all()
    assert float(jnp.max(jnp.abs(e))) < 100.0


def test_fwi_step_and_forward_consistent():
    h, w = model.FWI_H, model.FWI_W
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    p = jax.random.normal(ks[0], (h, w), jnp.float32)
    p = p.at[0].set(0).at[-1].set(0).at[:, 0].set(0).at[:, -1].set(0)
    p_prev = p * 0.9
    c2 = jnp.ones((h, w), jnp.float32)
    # forward8 == step applied 8 times.
    pf, pf_prev = jax.jit(lambda a, b, c: model.fwi_forward(a, b, c, steps=8))(p, p_prev, c2)
    ps, ps_prev = p, p_prev
    step = jax.jit(model.fwi_step)
    for _ in range(8):
        ps, ps_prev = step(ps, ps_prev, c2)
    np.testing.assert_allclose(np.asarray(pf), np.asarray(ps), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(pf_prev), np.asarray(ps_prev), rtol=1e-4, atol=1e-5)


def test_gershwin_step_shapes():
    b, d = model.GERSHWIN_B, model.GERSHWIN_D
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    e = jax.random.normal(ks[0], (b, d), jnp.float32)
    pol = jax.random.normal(ks[1], (b, d), jnp.float32)
    k = jax.random.normal(ks[2], (d, d), jnp.float32) / d
    f = jax.random.normal(ks[3], (b, d), jnp.float32)
    e2, p2 = jax.jit(model.gershwin_step)(e, pol, k, f)
    assert e2.shape == (b, d) and p2.shape == (b, d)


def test_nam_parity_matches_numpy():
    n, m = model.NAM_N, 4096
    blocks = jax.random.randint(jax.random.PRNGKey(6), (n, m), -2**31, 2**31 - 1, jnp.int32)
    got = np.asarray(jax.jit(model.nam_parity)(blocks))
    want = np.bitwise_xor.reduce(np.asarray(blocks), axis=0)
    assert (got == want).all()


def test_aot_entry_points_traceable():
    """Every AOT entry point lowers without error at its canonical shapes."""
    for name, fn, example_args in model.aot_entry_points():
        lowered = jax.jit(fn).lower(*example_args)
        assert lowered is not None, name

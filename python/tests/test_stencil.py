"""L1 correctness: FWI wave stencil and GERShWIN DGTD kernels vs oracles."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, stencil


# --------------------------------------------------------------------------
# FWI wave stencil
# --------------------------------------------------------------------------

def _wave_state(h, w, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    p = jax.random.normal(ks[0], (h, w), jnp.float32)
    # Zero the boundary ring so the Dirichlet conventions of kernel and
    # oracle coincide for all interior cells.
    p = p.at[0].set(0).at[-1].set(0).at[:, 0].set(0).at[:, -1].set(0)
    p_prev = p * 0.95
    c2 = jnp.abs(jax.random.normal(ks[2], (h, w), jnp.float32)) + 0.5
    return p, p_prev, c2


def test_wave_matches_ref():
    p, p_prev, c2 = _wave_state(66, 64)
    got = stencil.wave_step(p, p_prev, c2, dt=1e-3, dx=1e-2)
    want = ref.wave_step_ref(p, p_prev, c2, dt=1e-3, dx=1e-2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_wave_boundary_stays_zero():
    p, p_prev, c2 = _wave_state(34, 48, seed=1)
    out = np.asarray(stencil.wave_step(p, p_prev, c2, dt=1e-3, dx=1e-2))
    assert (out[0] == 0).all() and (out[-1] == 0).all()
    assert (out[:, 0] == 0).all() and (out[:, -1] == 0).all()


def test_wave_zero_field_stays_zero():
    z = jnp.zeros((66, 32), jnp.float32)
    c2 = jnp.ones_like(z)
    out = np.asarray(stencil.wave_step(z, z, c2, dt=1e-3, dx=1e-2))
    assert (out == 0).all()


def test_wave_cfl_stable_pulse_decays_slowly():
    """A centred Gaussian pulse under a CFL-stable step keeps bounded energy."""
    h = w = 66
    yy, xx = jnp.meshgrid(jnp.arange(h), jnp.arange(w), indexing="ij")
    p = jnp.exp(-((yy - h / 2) ** 2 + (xx - w / 2) ** 2) / 20.0).astype(jnp.float32)
    p = p.at[0].set(0).at[-1].set(0).at[:, 0].set(0).at[:, -1].set(0)
    p_prev = p
    c2 = jnp.ones_like(p)
    e0 = float(jnp.sum(p * p))
    for _ in range(20):
        p, p_prev = stencil.wave_step(p, p_prev, c2, dt=5e-3, dx=1e-2), p
    e1 = float(jnp.sum(p * p))
    assert np.isfinite(e1) and e1 < 4.0 * e0


@settings(max_examples=8, deadline=None)
@given(
    hb=st.integers(min_value=1, max_value=3),
    w=st.sampled_from([32, 64]),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_hypothesis_wave(hb, w, seed):
    h = hb * 32 + 2
    p, p_prev, c2 = _wave_state(h, w, seed=seed)
    got = stencil.wave_step(p, p_prev, c2, dt=1e-3, dx=1e-2)
    want = ref.wave_step_ref(p, p_prev, c2, dt=1e-3, dx=1e-2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


# --------------------------------------------------------------------------
# GERShWIN DGTD
# --------------------------------------------------------------------------

def _dgtd_state(b, d, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    e = jax.random.normal(ks[0], (b, d), jnp.float32)
    pol = jax.random.normal(ks[1], (b, d), jnp.float32) * 0.2
    k = jax.random.normal(ks[2], (d, d), jnp.float32) / d
    f = jax.random.normal(ks[3], (b, d), jnp.float32) * 0.1
    return e, pol, k, f


def test_dgtd_matches_ref():
    e, pol, k, f = _dgtd_state(512, 16)
    got_e, got_p = stencil.dgtd_step(e, pol, k, f, dt=1e-3, alpha=0.25, beta=0.5)
    want_e, want_p = ref.dgtd_step_ref(e, pol, k, f, dt=1e-3, alpha=0.25, beta=0.5)
    np.testing.assert_allclose(np.asarray(got_e), np.asarray(want_e), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got_p), np.asarray(want_p), rtol=1e-5, atol=1e-6)


def test_dgtd_zero_dt_identity():
    e, pol, k, f = _dgtd_state(128, 8, seed=5)
    got_e, got_p = stencil.dgtd_step(e, pol, k, f, dt=0.0, alpha=0.25, beta=0.5)
    np.testing.assert_allclose(np.asarray(got_e), np.asarray(e))
    np.testing.assert_allclose(np.asarray(got_p), np.asarray(pol))


def test_dgtd_debye_relaxation():
    """With K=0, f=0, e=0 the polarization decays geometrically at rate beta."""
    b, d = 64, 8
    pol = jnp.ones((b, d), jnp.float32)
    zeros = jnp.zeros((b, d), jnp.float32)
    k = jnp.zeros((d, d), jnp.float32)
    _, pol_new = stencil.dgtd_step(zeros, pol, k, zeros, dt=0.1, alpha=0.25, beta=0.5)
    np.testing.assert_allclose(np.asarray(pol_new), np.full((b, d), 1.0 - 0.1 * 0.5),
                               rtol=1e-6)


@settings(max_examples=8, deadline=None)
@given(
    bb=st.integers(min_value=1, max_value=4),
    d=st.sampled_from([4, 8, 16]),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_hypothesis_dgtd(bb, d, seed):
    b = bb * 64
    e, pol, k, f = _dgtd_state(b, d, seed=seed)
    got_e, got_p = stencil.dgtd_step(e, pol, k, f, dt=1e-3, alpha=0.25, beta=0.5)
    want_e, want_p = ref.dgtd_step_ref(e, pol, k, f, dt=1e-3, alpha=0.25, beta=0.5)
    np.testing.assert_allclose(np.asarray(got_e), np.asarray(want_e), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_p), np.asarray(want_p), rtol=1e-4, atol=1e-5)

"""L1 correctness: Boris push kernel vs oracle + physical invariants."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import pic, ref


def _state(n, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.uniform(ks[0], (n, 3), jnp.float32)
    v = jax.random.normal(ks[1], (n, 3), jnp.float32) * 0.1
    e = jax.random.normal(ks[2], (n, 3), jnp.float32)
    b = jax.random.normal(ks[3], (n, 3), jnp.float32)
    return x, v, e, b


def test_matches_ref():
    x, v, e, b = _state(1024)
    got_x, got_v = pic.boris_push(x, v, e, b, qm=-1.0, dt=0.01)
    want_x, want_v = ref.boris_push_ref(x, v, e, b, qm=-1.0, dt=0.01)
    np.testing.assert_allclose(np.asarray(got_x), np.asarray(want_x), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got_v), np.asarray(want_v), rtol=1e-6, atol=1e-6)


def test_pure_magnetic_preserves_speed():
    """E=0: the Boris rotation must conserve |v| exactly (to fp rounding)."""
    x, v, _, b = _state(512, seed=2)
    e = jnp.zeros_like(v)
    _, v_new = pic.boris_push(x, v, e, b, qm=-1.0, dt=0.05)
    s0 = np.linalg.norm(np.asarray(v), axis=1)
    s1 = np.linalg.norm(np.asarray(v_new), axis=1)
    np.testing.assert_allclose(s1, s0, rtol=1e-5)


def test_zero_fields_is_free_drift():
    x, v, _, _ = _state(256, seed=3)
    zeros = jnp.zeros_like(v)
    x_new, v_new = pic.boris_push(x, v, zeros, zeros, qm=-1.0, dt=0.25)
    np.testing.assert_allclose(np.asarray(v_new), np.asarray(v), rtol=1e-7)
    np.testing.assert_allclose(np.asarray(x_new), np.asarray(x + 0.25 * v), rtol=1e-6)


def test_zero_dt_is_identity():
    x, v, e, b = _state(256, seed=4)
    x_new, v_new = pic.boris_push(x, v, e, b, qm=-1.0, dt=0.0)
    np.testing.assert_allclose(np.asarray(x_new), np.asarray(x))
    np.testing.assert_allclose(np.asarray(v_new), np.asarray(v))


@settings(max_examples=10, deadline=None)
@given(
    n_tiles=st.integers(min_value=1, max_value=4),
    qm=st.floats(min_value=-5.0, max_value=5.0, allow_nan=False),
    dt=st.floats(min_value=0.0, max_value=0.2, allow_nan=False),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_hypothesis_push(n_tiles, qm, dt, seed):
    n = n_tiles * 256
    x, v, e, b = _state(n, seed=seed)
    got_x, got_v = pic.boris_push(x, v, e, b, qm=qm, dt=dt)
    want_x, want_v = ref.boris_push_ref(x, v, e, b, qm=qm, dt=dt)
    np.testing.assert_allclose(np.asarray(got_x), np.asarray(want_x), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_v), np.asarray(want_v), rtol=1e-4, atol=1e-5)

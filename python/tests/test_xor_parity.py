"""L1 correctness: NAM parity kernel vs oracle + RAID-5 reconstruction property."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, xor_parity


def _blocks(n, m, seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed), (n, m),
                              -2**31, 2**31 - 1, jnp.int32)


def test_matches_ref():
    blocks = _blocks(8, 8192)
    got = xor_parity.xor_parity(blocks)
    want = ref.xor_parity_ref(blocks)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_two_blocks_is_plain_xor():
    blocks = _blocks(2, 2048)
    got = np.asarray(xor_parity.xor_parity(blocks))
    want = np.asarray(blocks[0]) ^ np.asarray(blocks[1])
    assert (got == want).all()


def test_self_inverse():
    """parity ^ parity == 0 — XOR folding is an involution."""
    blocks = _blocks(4, 2048, seed=7)
    parity = np.asarray(xor_parity.xor_parity(blocks))
    assert ((parity ^ parity) == 0).all()


def test_reconstruction_any_single_loss():
    """The NAM XOR checkpoint property: any one lost block is recoverable
    from the parity and the survivors (paper Section III-D1)."""
    n, m = 6, 4096
    blocks = _blocks(n, m, seed=3)
    parity = np.asarray(xor_parity.xor_parity(blocks))
    host = np.asarray(blocks)
    for lost in range(n):
        rebuilt = parity.copy()
        for i in range(n):
            if i != lost:
                rebuilt ^= host[i]
        assert (rebuilt == host[lost]).all(), f"block {lost} not reconstructed"


def test_rejects_wrong_dtype():
    blocks = jnp.zeros((4, 2048), jnp.float32)
    with pytest.raises(TypeError, match="int32"):
        xor_parity.xor_parity(blocks)


def test_rejects_unaligned_m():
    # 10000 > TILE_M (so no clamping) and not a multiple of it.
    blocks = jnp.zeros((4, 10000), jnp.int32)
    with pytest.raises(ValueError, match="multiple"):
        xor_parity.xor_parity(blocks)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=16),
    m_tiles=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_hypothesis_parity(n, m_tiles, seed):
    m = m_tiles * 1024
    blocks = _blocks(n, m, seed=seed)
    got = xor_parity.xor_parity(blocks, tile_m=1024)
    want = ref.xor_parity_ref(blocks)
    assert (np.asarray(got) == np.asarray(want)).all()

"""L1/L2 perf analysis: VMEM footprints, arithmetic intensity, HLO stats.

interpret=True gives CPU-numpy timings only — NOT a TPU proxy — so the L1
optimization loop is *structural* (DESIGN.md section 8): per kernel we report
the VMEM-resident working set implied by the BlockSpecs, the arithmetic
intensity (flop/byte moved through the fast tier), and the estimated
MXU/VPU utilization class; per L2 artifact we count HLO ops and fusion
breaks in the lowered module.

Run:  cd python && python -m compile.roofline
"""

from __future__ import annotations

import re

import jax

from . import model
from .kernels import nbody, pic, stencil, xor_parity

VMEM_BUDGET = 16 * 1024 * 1024  # bytes per TPU core


def _mb(b: float) -> str:
    return f"{b / 1024:.1f} KB" if b < 1024 * 1024 else f"{b / 1048576:.2f} MB"


def kernel_reports():
    """(name, vmem_bytes, flops_per_invocation, bytes_streamed, notes)."""
    reports = []

    # nbody: i-tile resident (TILE_I x 3 f32 x2 for acc) + streamed j-tile.
    ti, tj, n = nbody.TILE_I, nbody.TILE_J, model.NBODY_N
    vmem = (ti * 3 + ti * 3 + tj * 3 + tj) * 4 + ti * tj * 4 * 4  # incl. (ti,tj,3)+r2 temps
    flops = 2.0 * 20 * n * n  # ~20 flop per pairwise interaction
    streamed = (n * 3 + n) * 4.0 * (n / ti)  # j-stream re-read per i-tile
    reports.append(("nbody_forces", vmem, flops, streamed,
                    f"i-tile {ti} resident, j streamed in {tj}-tiles; FMA-dense (VPU/MXU-adjacent)"))

    # boris push: 6 arrays x (TILE_P,3) resident, elementwise.
    tp = pic.TILE_P
    vmem = 6 * tp * 3 * 4
    flops = 60.0 * model.XPIC_P
    streamed = 4 * model.XPIC_P * 3 * 4.0
    reports.append(("boris_push", vmem, flops, streamed,
                    f"elementwise over {tp}-particle tiles; VPU bound, AI~{60/(16*3):.1f}"))

    # wave stencil: halo'd row block + 3 interior blocks.
    tr, w = stencil.TILE_ROWS, model.FWI_W
    vmem = ((tr + 2) * w + 3 * tr * w) * 4
    flops = 8.0 * model.FWI_H * model.FWI_W
    streamed = 4 * model.FWI_H * model.FWI_W * 4.0
    reports.append(("wave_step", vmem, flops, streamed,
                    f"{tr}-row blocks + 1-row halo; 5-point stencil, AI~0.5 (memory bound)"))

    # dgtd: element tile + shared (D,D) operator -> batched matmul on MXU.
    te, d = stencil.TILE_ELEMS, model.GERSHWIN_D
    vmem = (4 * te * d + d * d) * 4
    flops = 2.0 * model.GERSHWIN_B * d * d + 6.0 * model.GERSHWIN_B * d
    streamed = 5 * model.GERSHWIN_B * d * 4.0
    reports.append(("dgtd_step", vmem, flops, streamed,
                    f"(B={te})x({d}x{d}) batched matmul -> MXU; ADE update on VPU"))

    # xor parity: (N, TILE_M) window.
    tm, nn, mm = xor_parity.TILE_M, model.NAM_N, model.NAM_M
    vmem = (nn * tm + tm) * 4
    flops = 1.0 * nn * mm  # 1 int-op per word per block
    streamed = (nn + 1) * mm * 4.0
    reports.append(("xor_parity", vmem, flops, streamed,
                    f"{nn}-deep XOR fold over {tm}-word lanes; int VPU at stream rate"))

    return reports


def hlo_stats(name: str, fn, example_args):
    """Op-count + fusion stats of the lowered HLO for one L2 entry point."""
    lowered = jax.jit(fn).lower(*example_args)
    from .aot import to_hlo_text

    text = to_hlo_text(lowered)
    ops = re.findall(r"^\s*(?:ROOT\s+)?%?[\w.-]+\s*=\s*\S+\s+(\w+)\(", text, re.M)
    n_fusion = sum(1 for o in ops if o == "fusion")
    n_while = sum(1 for o in ops if o == "while")
    n_transpose = sum(1 for o in ops if o == "transpose")
    n_copy = sum(1 for o in ops if o == "copy")
    return {
        "name": name,
        "total_ops": len(ops),
        "fusions": n_fusion,
        "while_loops": n_while,
        "transposes": n_transpose,
        "copies": n_copy,
        "chars": len(text),
    }


def main() -> None:
    print("== L1: Pallas kernel working sets (VMEM budget 16 MB/core) ==")
    print(f"{'kernel':<14} {'VMEM':>10} {'util':>6} {'flops/call':>12} {'AI f/B':>7}  notes")
    for name, vmem, flops, streamed, notes in kernel_reports():
        util = vmem / VMEM_BUDGET * 100
        ai = flops / streamed
        print(f"{name:<14} {_mb(vmem):>10} {util:>5.1f}% {flops:>12.2e} {ai:>7.2f}  {notes}")
        assert vmem < VMEM_BUDGET, f"{name} exceeds VMEM budget"

    print()
    print("== L2: lowered HLO structure ==")
    print(f"{'artifact':<16} {'ops':>5} {'fusion':>7} {'while':>6} {'transp':>7} {'copy':>5} {'chars':>7}")
    for name, fn, args in model.aot_entry_points():
        st = hlo_stats(name, fn, args)
        print(
            f"{st['name']:<16} {st['total_ops']:>5} {st['fusions']:>7} "
            f"{st['while_loops']:>6} {st['transposes']:>7} {st['copies']:>5} {st['chars']:>7}"
        )


if __name__ == "__main__":
    main()

"""L1 Pallas kernel: tiled all-pairs N-body gravity forces.

This is the compute hot-spot of the DEEP-ER N-body co-design code (Fig. 4 of
the paper).  The kernel follows the classic tile-the-interaction pattern,
re-thought for the TPU memory hierarchy per DESIGN.md section
"Hardware-Adaptation":

  * i-particles are resident in VMEM (one BlockSpec tile per grid step),
  * j-particles are streamed tile-by-tile with an accumulating fori_loop,
  * the inner pairwise update is a dense f32 FMA pipeline (VPU/MXU friendly).

``interpret=True`` is mandatory: real-TPU lowering emits a Mosaic custom-call
the CPU PJRT plugin cannot execute.  Correctness is pinned against
``ref.nbody_forces_ref`` by pytest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes (perf pass, EXPERIMENTS.md section Perf L1 iteration 1:
# 128 -> 256 halves the number of full j-stream passes over HBM and
# amortizes grid overhead; the (TILE_I, TILE_J, 3) pairwise temporaries
# reach ~1 MB VMEM, ~6% of the 16 MB budget).
TILE_I = 256
TILE_J = 256


def _nbody_kernel(pos_i_ref, pos_all_ref, mass_all_ref, acc_ref, *, eps2: float, tile_j: int):
    """One grid step: forces on a tile of i-particles from all j-particles."""
    pos_i = pos_i_ref[...]  # (TILE_I, 3) resident tile
    n_j = pos_all_ref.shape[0]
    n_tiles = n_j // tile_j

    def body(jt, acc):
        # Stream one j-tile from the full (HBM-resident) particle array.
        pos_j = pl.load(pos_all_ref, (pl.dslice(jt * tile_j, tile_j), slice(None)))
        mass_j = pl.load(mass_all_ref, (pl.dslice(jt * tile_j, tile_j),))
        # Pairwise displacement (TILE_I, tile_j, 3): the dense FMA core.
        d = pos_j[None, :, :] - pos_i[:, None, :]
        r2 = jnp.sum(d * d, axis=-1) + eps2
        inv_r = jax.lax.rsqrt(r2)
        w = mass_j[None, :] * inv_r * inv_r * inv_r  # m_j / r^3
        return acc + jnp.sum(w[:, :, None] * d, axis=1)

    acc = jax.lax.fori_loop(0, n_tiles, body, jnp.zeros_like(pos_i))
    acc_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("tile_i", "tile_j"))
def nbody_forces(pos: jax.Array, mass: jax.Array, *, eps2: float = 1e-4,
                 tile_i: int = TILE_I, tile_j: int = TILE_J) -> jax.Array:
    """Gravitational accelerations ``a_i = sum_j m_j (x_j - x_i) / (r^2+eps2)^1.5``.

    Args:
      pos:  (N, 3) f32 particle positions; N must be a multiple of the tiles.
      mass: (N,)   f32 particle masses.
    Returns:
      (N, 3) f32 accelerations.
    """
    n = pos.shape[0]
    tile_i = min(tile_i, n)
    tile_j = min(tile_j, n)
    if n % tile_i or n % tile_j:
        raise ValueError(f"N={n} must be a multiple of tile_i={tile_i} and tile_j={tile_j}")
    kernel = functools.partial(_nbody_kernel, eps2=eps2, tile_j=tile_j)
    return pl.pallas_call(
        kernel,
        grid=(n // tile_i,),
        in_specs=[
            pl.BlockSpec((tile_i, 3), lambda i: (i, 0)),       # resident i-tile
            pl.BlockSpec((n, 3), lambda i: (0, 0)),            # streamed j-source
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_i, 3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 3), pos.dtype),
        interpret=True,  # CPU-PJRT execution; Mosaic path is TPU-only
    )(pos, pos, mass)


def nbody_forces_call(pos: jax.Array, mass: jax.Array, eps2: float = 1e-4) -> jax.Array:
    """Non-jit wrapper used by model.py inside larger jitted graphs."""
    n = pos.shape[0]
    tile_i = min(TILE_I, n)
    tile_j = min(TILE_J, n)
    kernel = functools.partial(_nbody_kernel, eps2=eps2, tile_j=tile_j)
    return pl.pallas_call(
        kernel,
        grid=(n // tile_i,),
        in_specs=[
            pl.BlockSpec((tile_i, 3), lambda i: (i, 0)),
            pl.BlockSpec((n, 3), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_i, 3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 3), pos.dtype),
        interpret=True,
    )(pos, pos, mass)

"""L1 Pallas kernels: FWI acoustic wave stencil and GERShWIN DGTD element update.

FWI (paper Section IV, Fig. 10) propagates acoustic waves through a velocity
model: a 2nd-order-in-time, 2nd-order-in-space scheme over a 2D pressure
field,

    p_next = 2 p - p_prev + (c dt / dx)^2 * lap(p)

with homogeneous Dirichlet boundaries.  The stencil is expressed over a
halo-padded VMEM-resident block: the interior block rows are the Pallas grid,
each grid step loads its block plus a one-cell halo (overlapping BlockSpec
reads are legal — blocks are read-only).

GERShWIN (Fig. 5) is a Discontinuous Galerkin Time Domain solver for the 3D
Maxwell-Debye system.  Its hot loop is element-local dense algebra: for each
element, apply the stiffness/flux operator to the local dofs and integrate
the Debye polarization ODE (auxiliary differential equation).  That maps onto
the MXU as a batched (elements x dof x dof) matmul — exactly the shape the
systolic array wants — plus an elementwise ADE update on the VPU:

    e' = e + dt * (K e + f - p)
    p' = p + dt * (alpha e - beta p)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_ROWS = 64   # interior rows per FWI grid step (perf pass: 32 -> 64)
TILE_ELEMS = 64  # DGTD elements per grid step


# --------------------------------------------------------------------------
# FWI: 5-point acoustic wave stencil
# --------------------------------------------------------------------------

def _wave_kernel(p_ref, p_prev_ref, c2_ref, out_ref, *, coef: float, tile: int):
    """Row-block r: read rows [r*T, r*T+T+2) of halo'd p, write T interior rows.

    ``p_ref`` is the full (H, W) field; the halo'd row window is streamed in
    with an explicit dynamic slice (this is the HBM->VMEM schedule: block r
    overlaps its neighbours by one halo row on each side).
    """
    r = pl.program_id(0)
    w = p_ref.shape[1]
    p = pl.load(p_ref, (pl.dslice(r * tile, tile + 2), slice(None)))  # (T+2, W)
    p_prev = p_prev_ref[...]  # (T, W) interior rows of this block
    c2 = c2_ref[...]          # (T, W)
    lap_i = (p[:-2, 1:-1] + p[2:, 1:-1] + p[1:-1, :-2] + p[1:-1, 2:]
             - 4.0 * p[1:-1, 1:-1])
    interior = (2.0 * p[1:-1, 1:-1] - p_prev[:, 1:-1]
                + coef * c2[:, 1:-1] * lap_i)
    out_ref[...] = jnp.pad(interior, ((0, 0), (1, 1)))  # zero Dirichlet in x


def wave_step_call(p: jax.Array, p_prev: jax.Array, c2: jax.Array,
                   *, dt: float, dx: float) -> jax.Array:
    """One wave-equation step on an (H, W) f32 grid, Dirichlet boundaries.

    ``c2`` is squared velocity per cell.  H-2 must be a multiple of
    TILE_ROWS (the boundary rows stay zero and are written by padding).
    """
    h, w = p.shape
    interior_rows = h - 2
    tile = TILE_ROWS if interior_rows % TILE_ROWS == 0 else interior_rows
    if interior_rows % tile:
        raise ValueError(f"H-2={interior_rows} not divisible by tile={tile}")
    coef = (dt / dx) ** 2

    kernel = functools.partial(_wave_kernel, coef=coef, tile=tile)
    interior = pl.pallas_call(
        kernel,
        grid=(interior_rows // tile,),
        in_specs=[
            pl.BlockSpec((h, w), lambda r: (0, 0)),  # full field; halo'd slice in-kernel
            pl.BlockSpec((tile, w), lambda r: (r, 0)),
            pl.BlockSpec((tile, w), lambda r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((tile, w), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((interior_rows, w), p.dtype),
        interpret=True,  # CPU-PJRT execution; Mosaic path is TPU-only
    )(p, p_prev[1:-1], c2[1:-1])
    return jnp.pad(interior, ((1, 1), (0, 0)))  # zero Dirichlet in y


@functools.partial(jax.jit, static_argnames=("dt", "dx"))
def wave_step(p, p_prev, c2, *, dt: float, dx: float):
    return wave_step_call(p, p_prev, c2, dt=dt, dx=dx)


# --------------------------------------------------------------------------
# GERShWIN: DGTD Maxwell-Debye element update
# --------------------------------------------------------------------------

def _dgtd_kernel(e_ref, pol_ref, k_ref, f_ref, eo_ref, po_ref,
                 *, dt: float, alpha: float, beta: float):
    e = e_ref[...]      # (T, D) element dofs
    pol = pol_ref[...]  # (T, D) Debye polarization dofs
    k = k_ref[...]      # (D, D) shared element operator
    f = f_ref[...]      # (T, D) flux/source term
    # Batched dense operator application: the MXU-shaped core.
    ke = jnp.dot(e, k.T, preferred_element_type=jnp.float32)
    eo_ref[...] = e + dt * (ke + f - pol)
    po_ref[...] = pol + dt * (alpha * e - beta * pol)


def dgtd_step_call(e: jax.Array, pol: jax.Array, k: jax.Array, f: jax.Array,
                   *, dt: float, alpha: float, beta: float) -> tuple[jax.Array, jax.Array]:
    """One DGTD Maxwell-Debye step.

    e, pol, f: (B, D) f32 per-element dof vectors; k: (D, D) shared operator.
    Returns (e_new, pol_new).
    """
    b, d = e.shape
    tile = min(TILE_ELEMS, b)
    if b % tile:
        raise ValueError(f"B={b} must be a multiple of tile={tile}")
    kernel = functools.partial(_dgtd_kernel, dt=dt, alpha=alpha, beta=beta)
    espec = pl.BlockSpec((tile, d), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=(b // tile,),
        in_specs=[espec, espec, pl.BlockSpec((d, d), lambda i: (0, 0)), espec],
        out_specs=(espec, espec),
        out_shape=(
            jax.ShapeDtypeStruct((b, d), e.dtype),
            jax.ShapeDtypeStruct((b, d), pol.dtype),
        ),
        interpret=True,  # CPU-PJRT execution; Mosaic path is TPU-only
    )(e, pol, k, f)


@functools.partial(jax.jit, static_argnames=("dt", "alpha", "beta"))
def dgtd_step(e, pol, k, f, *, dt: float, alpha: float, beta: float):
    return dgtd_step_call(e, pol, k, f, dt=dt, alpha=alpha, beta=beta)

"""L1 Pallas kernel: xPic particle push (Boris rotation, Moment-Implicit form).

xPic (paper Section IV) is a particle-in-cell space-weather code with two
halves: a particle solver (motion of charged particles in the EM field +
moment gathering) and a field solver.  The particle push is the compute
hot-spot — O(N_particles) per step with a dense FMA pipeline — and is the
part DEEP-ER ran on the KNL Booster, blocked for MCDRAM.  Here it is blocked
for VMEM instead: one particle tile resident per grid step, fields already
gathered to the particles by the L2 model (model.xpic_step), so the kernel is
purely elementwise over the tile.

The Boris scheme (velocity half-kick, magnetic rotation, half-kick, drift):
    v^- = v + (q/m) (dt/2) E
    t   = (q/m) (dt/2) B
    v'  = v^- + v^- x t
    v^+ = v^- + 2/(1+|t|^2) (v' x t)
    v_new = v^+ + (q/m)(dt/2) E
    x_new = x + dt v_new
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_P = 1024  # particles per VMEM-resident tile (perf pass: 256 -> 1024)


def _cross(a, b):
    """Cross product over the trailing axis=1 of (T, 3) tiles."""
    ax, ay, az = a[:, 0], a[:, 1], a[:, 2]
    bx, by, bz = b[:, 0], b[:, 1], b[:, 2]
    return jnp.stack([ay * bz - az * by, az * bx - ax * bz, ax * by - ay * bx], axis=1)


def _push_kernel(x_ref, v_ref, e_ref, b_ref, xo_ref, vo_ref, *, qm: float, dt: float):
    x = x_ref[...]
    v = v_ref[...]
    e = e_ref[...]
    b = b_ref[...]
    half = qm * dt * 0.5
    v_minus = v + half * e
    t = half * b
    v_prime = v_minus + _cross(v_minus, t)
    s = 2.0 / (1.0 + jnp.sum(t * t, axis=1, keepdims=True))
    v_plus = v_minus + s * _cross(v_prime, t)
    v_new = v_plus + half * e
    xo_ref[...] = x + dt * v_new
    vo_ref[...] = v_new


def boris_push_call(x: jax.Array, v: jax.Array, e: jax.Array, b: jax.Array,
                    *, qm: float, dt: float) -> tuple[jax.Array, jax.Array]:
    """Push all particles one step.  All arrays are (N, 3) f32.

    ``e``/``b`` are the fields already interpolated to particle positions
    (the gather lives in L2 where XLA fuses it with the grid interpolation).
    Returns (x_new, v_new).
    """
    n = x.shape[0]
    tile = min(TILE_P, n)
    if n % tile:
        raise ValueError(f"N={n} must be a multiple of tile={tile}")
    kernel = functools.partial(_push_kernel, qm=qm, dt=dt)
    spec = pl.BlockSpec((tile, 3), lambda i: (i, 0))
    return pl.pallas_call(
        kernel,
        grid=(n // tile,),
        in_specs=[spec, spec, spec, spec],
        out_specs=(spec, spec),
        out_shape=(
            jax.ShapeDtypeStruct((n, 3), x.dtype),
            jax.ShapeDtypeStruct((n, 3), v.dtype),
        ),
        interpret=True,  # CPU-PJRT execution; Mosaic path is TPU-only
    )(x, v, e, b)


@functools.partial(jax.jit, static_argnames=("qm", "dt"))
def boris_push(x, v, e, b, *, qm: float, dt: float):
    """Jitted standalone entry point (tests, benchmarking)."""
    return boris_push_call(x, v, e, b, qm=qm, dt=dt)

"""Pure-jnp oracles for every Pallas kernel.

These are the correctness anchors: no Pallas, no tiling — straightforward
dense jnp formulations of the same math.  pytest (python/tests/) pins every
kernel against its oracle, and hypothesis sweeps shapes/seeds.  They are
also what the L1 perf targets are measured against (>=0.5x of the pure-jnp
reference's roofline, per DESIGN.md section 8).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def nbody_forces_ref(pos: jax.Array, mass: jax.Array, eps2: float = 1e-4) -> jax.Array:
    """a_i = sum_j m_j (x_j - x_i) / (|x_j - x_i|^2 + eps2)^(3/2)."""
    d = pos[None, :, :] - pos[:, None, :]            # (N, N, 3)
    r2 = jnp.sum(d * d, axis=-1) + eps2              # (N, N)
    inv_r3 = r2 ** -1.5
    w = mass[None, :] * inv_r3
    return jnp.sum(w[:, :, None] * d, axis=1)


def xor_parity_ref(blocks: jax.Array) -> jax.Array:
    """Fold (N, M) int32 blocks with XOR along axis 0."""
    out = blocks[0]
    for i in range(1, blocks.shape[0]):
        out = out ^ blocks[i]
    return out


def boris_push_ref(x, v, e, b, *, qm: float, dt: float):
    """Textbook Boris push; all arrays (N, 3) f32."""
    half = qm * dt * 0.5
    v_minus = v + half * e
    t = half * b
    v_prime = v_minus + jnp.cross(v_minus, t)
    s = 2.0 / (1.0 + jnp.sum(t * t, axis=1, keepdims=True))
    v_plus = v_minus + s * jnp.cross(v_prime, t)
    v_new = v_plus + half * e
    return x + dt * v_new, v_new


def wave_step_ref(p, p_prev, c2, *, dt: float, dx: float):
    """2nd-order acoustic wave step, zero Dirichlet boundary ring."""
    coef = (dt / dx) ** 2
    lap = (jnp.roll(p, 1, 0) + jnp.roll(p, -1, 0)
           + jnp.roll(p, 1, 1) + jnp.roll(p, -1, 1) - 4.0 * p)
    out = 2.0 * p - p_prev + coef * c2 * lap
    out = out.at[0, :].set(0.0).at[-1, :].set(0.0)
    out = out.at[:, 0].set(0.0).at[:, -1].set(0.0)
    return out


def dgtd_step_ref(e, pol, k, f, *, dt: float, alpha: float, beta: float):
    """Element-local DGTD Maxwell-Debye update."""
    ke = e @ k.T
    e_new = e + dt * (ke + f - pol)
    pol_new = pol + dt * (alpha * e - beta * pol)
    return e_new, pol_new

"""L1 Pallas kernel: streaming XOR parity over N checkpoint blocks.

Models the NAM's FPGA parity datapath (paper Section II-B2 and the *NAM XOR*
checkpoint strategy of Section III-D1): the FPGA pulls one checkpoint block
per node over EXTOLL and folds them into a single parity block stored in the
HMC.  Here the same dataflow is expressed for the TPU model: the node
dimension is streamed through VMEM with an accumulate-XOR on the VPU's
integer lanes, the parity-column dimension is the Pallas grid.

The rust ``nam::ParityEngine`` mirrors this computation bit-for-bit; the
proptest/ hypothesis suites assert the RAID-5 style reconstruction property
(parity ^ all-but-one == the missing block) on both sides.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 8192 int32 lanes * 4 B = 32 KiB per streamed row (perf pass: 2048 -> 8192
# quarters the grid-step count); with N<=64 nodes the resident window stays
# ~2 MB of VMEM.
TILE_M = 8192


def _xor_kernel(blocks_ref, parity_ref):
    """parity = blocks[0] ^ blocks[1] ^ ... ^ blocks[N-1] (one M-tile)."""
    n = blocks_ref.shape[0]

    def body(i, acc):
        return acc ^ blocks_ref[i, :]

    parity_ref[...] = jax.lax.fori_loop(1, n, body, blocks_ref[0, :])


@functools.partial(jax.jit, static_argnames=("tile_m",))
def xor_parity(blocks: jax.Array, *, tile_m: int = TILE_M) -> jax.Array:
    """XOR-fold ``blocks`` of shape (N, M) int32 into a parity row (M,) int32.

    N is the number of participating nodes (>= 2), M the block length in
    32-bit words.  M must be a multiple of ``tile_m`` (pad at the caller —
    scr::dist_xor and nam::ParityEngine both pad to the chunk size).
    """
    n, m = blocks.shape
    if blocks.dtype != jnp.int32:
        raise TypeError(f"parity blocks must be int32, got {blocks.dtype}")
    tile_m = min(tile_m, m)
    if m % tile_m:
        raise ValueError(f"M={m} must be a multiple of tile_m={tile_m}")
    return pl.pallas_call(
        _xor_kernel,
        grid=(m // tile_m,),
        in_specs=[pl.BlockSpec((n, tile_m), lambda j: (0, j))],
        out_specs=pl.BlockSpec((tile_m,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.int32),
        interpret=True,  # CPU-PJRT execution; Mosaic path is TPU-only
    )(blocks)


def xor_parity_call(blocks: jax.Array) -> jax.Array:
    """Non-jit wrapper for composition inside model.py graphs."""
    n, m = blocks.shape
    tile_m = min(TILE_M, m)
    return pl.pallas_call(
        _xor_kernel,
        grid=(m // tile_m,),
        in_specs=[pl.BlockSpec((n, tile_m), lambda j: (0, j))],
        out_specs=pl.BlockSpec((tile_m,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.int32),
        interpret=True,
    )(blocks)

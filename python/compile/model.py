"""L2: JAX step functions for the DEEP-ER co-design applications.

Each application from the paper (Section IV) gets a jit-able step function
composed from the L1 Pallas kernels in ``kernels/``:

  * ``nbody_step``    — the N-body code used for the Fig. 4 checkpoint study
                        (leapfrog over the tiled Pallas force kernel).
  * ``xpic_step``     — xPic's particle solver + a compact moment/field
                        update: gather E/B to particles (fused by XLA with
                        the interpolation), Boris push (Pallas), charge/current
                        deposit via segment-sum, damped field relaxation.
  * ``fwi_step``      — FWI acoustic wave propagation (Pallas stencil), plus
                        a scanned multi-step variant (scan keeps the lowered
                        HLO small; see DESIGN.md section 8, L2 perf).
  * ``gershwin_step`` — GERShWIN's DGTD Maxwell-Debye element update
                        (Pallas batched dense operator + ADE).
  * ``nam_parity``    — the NAM FPGA's XOR parity fold (Pallas), used by the
                        NAM XOR checkpoint strategy.

This module is **build-time only**: ``aot.py`` lowers every entry point to
HLO text in ``artifacts/`` exactly once; the rust coordinator executes the
artifacts through PJRT and Python never appears on the request path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.nbody import nbody_forces_call
from .kernels.pic import boris_push_call
from .kernels.stencil import dgtd_step_call, wave_step_call
from .kernels.xor_parity import xor_parity_call

# --------------------------------------------------------------------------
# N-body (Fig. 4 workload)
# --------------------------------------------------------------------------

NBODY_DT = 1e-3
NBODY_EPS2 = 1e-4


def nbody_step(pos: jax.Array, vel: jax.Array, mass: jax.Array):
    """One leapfrog (kick-drift) step.  pos/vel (N,3) f32, mass (N,) f32."""
    acc = nbody_forces_call(pos, mass, eps2=NBODY_EPS2)
    vel = vel + NBODY_DT * acc
    pos = pos + NBODY_DT * vel
    return pos, vel


def nbody_energy(pos: jax.Array, vel: jax.Array, mass: jax.Array):
    """Total energy diagnostic (kinetic + softened potential); a scalar."""
    kin = 0.5 * jnp.sum(mass * jnp.sum(vel * vel, axis=1))
    d = pos[None, :, :] - pos[:, None, :]
    r = jnp.sqrt(jnp.sum(d * d, axis=-1) + NBODY_EPS2)
    pair = mass[None, :] * mass[:, None] / r
    pot = -0.5 * (jnp.sum(pair) - jnp.sum(mass * mass) / jnp.sqrt(NBODY_EPS2))
    return kin + pot


# --------------------------------------------------------------------------
# xPic (Figs. 6-9 workload): compact Moment-Implicit PIC mock-up
# --------------------------------------------------------------------------

XPIC_QM = -1.0       # charge/mass ratio
XPIC_DT = 0.05
XPIC_L = 1.0         # periodic box length
XPIC_DECAY = 0.95    # field relaxation factor (stands in for the implicit solve)


def _cell_index(x: jax.Array, grid: int) -> jax.Array:
    """Nearest-cell index per particle, periodic box, flattened (G^3)."""
    g = jnp.floor(x / XPIC_L * grid).astype(jnp.int32) % grid
    return (g[:, 0] * grid + g[:, 1]) * grid + g[:, 2]


def xpic_step(x: jax.Array, v: jax.Array, e_grid: jax.Array, b_grid: jax.Array):
    """One xPic particle-solver + field-relaxation step.

    x, v:          (P, 3) f32 particle positions (in [0, L)^3) and velocities.
    e_grid/b_grid: (G^3, 3) f32 fields on the flattened periodic grid.
    Returns (x', v', e_grid', rho): updated state + charge density (G^3,).
    """
    grid = round(int(e_grid.shape[0]) ** (1.0 / 3.0))
    cells = _cell_index(x, grid)
    # Gather fields to particles (XLA fuses gather + push prologue).
    e_p = e_grid[cells]
    b_p = b_grid[cells]
    # L1 hot-spot: Boris push over VMEM-resident particle tiles.
    x_new, v_new = boris_push_call(x, v, e_p, b_p, qm=XPIC_QM, dt=XPIC_DT)
    x_new = jnp.mod(x_new, XPIC_L)
    # Moment gathering: charge + current density per cell (segment-sum).
    cells_new = _cell_index(x_new, grid)
    n_cells = grid ** 3
    rho = jax.ops.segment_sum(jnp.ones_like(x_new[:, 0]), cells_new, n_cells)
    cur = jax.ops.segment_sum(v_new, cells_new, n_cells)
    # Field solver stand-in: damped response to the gathered moments.
    mean_rho = jnp.mean(rho)
    e_new = XPIC_DECAY * e_grid - (1.0 - XPIC_DECAY) * (
        cur / (1.0 + rho)[:, None] + (rho - mean_rho)[:, None] * 0.1
    )
    return x_new, v_new, e_new, rho


# --------------------------------------------------------------------------
# FWI (Fig. 10 workload)
# --------------------------------------------------------------------------

FWI_DT = 1e-3
FWI_DX = 1e-2


def fwi_step(p: jax.Array, p_prev: jax.Array, c2: jax.Array):
    """One acoustic wave step; all (H, W) f32.  Returns (p', p)."""
    p_new = wave_step_call(p, p_prev, c2, dt=FWI_DT, dx=FWI_DX)
    return p_new, p


def fwi_forward(p: jax.Array, p_prev: jax.Array, c2: jax.Array, steps: int = 8):
    """``steps`` scanned wave steps (scan, not unroll: small HLO, no
    recompilation per horizon — the L2 perf choice called out in DESIGN.md)."""

    def body(carry, _):
        p, p_prev = carry
        return fwi_step(p, p_prev, c2), None

    (p, p_prev), _ = jax.lax.scan(body, (p, p_prev), None, length=steps)
    return p, p_prev


# --------------------------------------------------------------------------
# GERShWIN (Fig. 5 workload)
# --------------------------------------------------------------------------

GERSHWIN_DT = 1e-3
GERSHWIN_ALPHA = 0.25   # Debye ADE: eps_d / tau
GERSHWIN_BETA = 0.50    # Debye ADE: 1 / tau


def gershwin_step(e: jax.Array, pol: jax.Array, k: jax.Array, f: jax.Array):
    """One DGTD Maxwell-Debye step.  e/pol/f (B, D) f32, k (D, D) f32."""
    return dgtd_step_call(e, pol, k, f, dt=GERSHWIN_DT,
                          alpha=GERSHWIN_ALPHA, beta=GERSHWIN_BETA)


# --------------------------------------------------------------------------
# NAM parity engine (Fig. 9 workload)
# --------------------------------------------------------------------------

def nam_parity(blocks: jax.Array) -> jax.Array:
    """XOR-fold (N, M) int32 checkpoint blocks into one (M,) parity row."""
    return xor_parity_call(blocks)


# --------------------------------------------------------------------------
# Canonical AOT shapes (shared by aot.py and the pytest contracts)
# --------------------------------------------------------------------------

NBODY_N = 1024
XPIC_P = 4096
XPIC_G = 16
FWI_H, FWI_W = 130, 128
GERSHWIN_B, GERSHWIN_D = 512, 16
NAM_N, NAM_M = 8, 65536


def aot_entry_points():
    """(name, fn, example_args) for every artifact aot.py emits."""
    f32 = jnp.float32
    i32 = jnp.int32
    s = jax.ShapeDtypeStruct
    return [
        ("nbody_step", nbody_step,
         (s((NBODY_N, 3), f32), s((NBODY_N, 3), f32), s((NBODY_N,), f32))),
        ("nbody_energy", nbody_energy,
         (s((NBODY_N, 3), f32), s((NBODY_N, 3), f32), s((NBODY_N,), f32))),
        ("xpic_step", xpic_step,
         (s((XPIC_P, 3), f32), s((XPIC_P, 3), f32),
          s((XPIC_G ** 3, 3), f32), s((XPIC_G ** 3, 3), f32))),
        ("fwi_step", fwi_step,
         (s((FWI_H, FWI_W), f32), s((FWI_H, FWI_W), f32), s((FWI_H, FWI_W), f32))),
        ("fwi_forward8", lambda p, pp, c2: fwi_forward(p, pp, c2, steps=8),
         (s((FWI_H, FWI_W), f32), s((FWI_H, FWI_W), f32), s((FWI_H, FWI_W), f32))),
        ("gershwin_step", gershwin_step,
         (s((GERSHWIN_B, GERSHWIN_D), f32), s((GERSHWIN_B, GERSHWIN_D), f32),
          s((GERSHWIN_D, GERSHWIN_D), f32), s((GERSHWIN_B, GERSHWIN_D), f32))),
        ("nam_parity", nam_parity, (s((NAM_N, NAM_M), i32),)),
    ]

"""AOT compile path: lower every L2 entry point to HLO text + a manifest.

Interchange format is **HLO text**, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what the
published ``xla`` 0.1.6 crate links) rejects (``proto.id() <= INT_MAX``); the
text parser reassigns ids, so text round-trips cleanly.  See
/opt/xla-example/README.md and gen_hlo.py.

Outputs (under --out-dir, default ``artifacts/``):
  <name>.hlo.txt   — one per entry point in model.aot_entry_points()
  manifest.json    — input/output shapes+dtypes per artifact, consumed by
                     rust/src/runtime to marshal PJRT literals.

Run via ``make artifacts`` (a no-op when inputs are unchanged).  This is the
only place Python runs; the rust binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
from jax._src.lib import xla_client as xc

from . import model

_DTYPE_NAMES = {"float32": "f32", "int32": "i32", "float64": "f64", "int64": "i64"}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    rust side always unwraps a tuple, even for single-output functions)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(aval) -> dict:
    dtype = _DTYPE_NAMES.get(str(aval.dtype), str(aval.dtype))
    return {"shape": list(aval.shape), "dtype": dtype}


def lower_all(out_dir: pathlib.Path) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    manifest = {"format": "hlo-text", "artifacts": []}
    for name, fn, example_args in model.aot_entry_points():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        (out_dir / fname).write_text(text)
        out_avals = lowered.out_info
        flat_out, _ = jax.tree_util.tree_flatten(out_avals)
        manifest["artifacts"].append({
            "name": name,
            "file": fname,
            "inputs": [_spec(a) for a in example_args],
            "outputs": [_spec(o) for o in flat_out],
        })
        print(f"  {fname}: {len(text)} chars, "
              f"{len(example_args)} inputs -> {len(flat_out)} outputs")
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts",
                    help="artifact directory (default: ../artifacts, i.e. repo root)")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    print(f"lowering {len(model.aot_entry_points())} entry points -> {out_dir}")
    lower_all(out_dir)
    print("aot done")


if __name__ == "__main__":
    main()

//! Offline **stub** of the xla-rs PJRT bindings.
//!
//! The real compute path of the reproduction (`deeper::runtime`) executes
//! AOT-lowered HLO artifacts through PJRT.  The PJRT C++ runtime is not
//! available in this offline build environment, so this crate provides the
//! exact API surface `deeper::runtime` consumes — every entry point
//! compiles, and the first one that would touch real hardware
//! ([`PjRtClient::cpu`]) returns [`Error::Unavailable`] instead.  Callers
//! therefore degrade gracefully: `Runtime::open` fails with a clear
//! message, and the PJRT integration tests skip themselves.
//!
//! To run the real path, replace this path dependency in the workspace
//! `Cargo.toml` with the actual `xla` bindings and re-run `make artifacts`
//! to produce `artifacts/*.hlo.txt` + `manifest.json`.

use std::fmt;

/// Stub error: PJRT is not available in this build.
#[derive(Debug, Clone)]
pub enum Error {
    /// The stub was asked to perform real PJRT work.
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "xla stub: {what} unavailable (offline build; vendor/xla is a stub — \
                 see DESIGN.md, section 'Simulation vs real compute')"
            ),
        }
    }
}

impl std::error::Error for Error {}

/// Stub result alias matching the real bindings.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// Element types the reproduction's manifests use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    /// 32-bit IEEE-754 float.
    F32,
    /// 32-bit signed integer.
    S32,
}

/// A host-side literal (stub: carries no data).
#[derive(Debug)]
pub struct Literal(());

impl Literal {
    /// Build a literal from raw bytes (stub: always errors).
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        unavailable("Literal::create_from_shape_and_untyped_data")
    }

    /// Copy the literal out as a typed vector (stub: always errors).
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    /// Destructure a tuple-shaped literal (stub: always errors).
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// An HLO module parsed from text (stub: always errors on load).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Parse an `*.hlo.txt` artifact (stub: always errors).
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// A computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// A compiled, device-loaded executable (stub: never obtainable).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with the given arguments (stub: always errors).
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// A device buffer holding one execution output.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    /// Transfer the buffer to a host literal (stub: always errors).
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// The PJRT client. [`PjRtClient::cpu`] is the stub's choke point: it
/// errors before any caller can reach the other entry points with real
/// work.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    /// Create a CPU client (stub: always errors — this is the documented
    /// "PJRT unavailable offline" failure).
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    /// Compile a computation (stub: always errors).
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        let text = err.to_string();
        assert!(text.contains("stub"), "{text}");
        assert!(text.contains("PjRtClient::cpu"), "{text}");
    }

    #[test]
    fn literal_paths_report_unavailable() {
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2], &[0; 8])
            .is_err());
        assert!(HloModuleProto::from_text_file("nope.hlo.txt").is_err());
    }
}

//! Offline stand-in for the [`anyhow`](https://docs.rs/anyhow) crate.
//!
//! The DEEP-ER reproduction builds in an environment without crates.io
//! access, so this vendored crate provides the (small) subset of the real
//! `anyhow` API the tree actually uses: [`Error`], [`Result`], and the
//! [`anyhow!`], [`bail!`] and [`ensure!`] macros, plus the blanket
//! `From<E: std::error::Error>` conversion that makes `?` work.  The
//! semantics match the real crate closely enough that swapping in the
//! genuine dependency is a one-line change in the workspace manifest.

use std::error::Error as StdError;
use std::fmt;

/// A type-erased error with an optional source chain, mirroring
/// `anyhow::Error`.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Create an error from a displayable message (what [`anyhow!`] calls).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { msg: message.to_string(), source: None }
    }

    /// Wrap a concrete `std::error::Error` value.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Self {
        Self { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    /// The wrapped error's source, matching real `anyhow` (where `Error`
    /// derefs to the wrapped `dyn Error`, so `.source()` is the *next*
    /// level down, not the wrapped error itself).
    pub fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.source
            .as_deref()
            .and_then(|e| (e as &(dyn StdError + 'static)).source())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        // The wrapped error's Display is already `self.msg`, so the
        // "Caused by" chain starts one level below it (as real anyhow does).
        let mut cause = self.source();
        if cause.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cause {
            write!(f, "\n    {e}")?;
            cause = e.source();
        }
        Ok(())
    }
}

// The blanket conversion that powers `?`.  `Error` itself deliberately does
// NOT implement `std::error::Error` (same as the real anyhow), otherwise
// this impl would overlap the reflexive `From<T> for T`.
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// `Result<T, anyhow::Error>`, with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is not satisfied.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!(
                ::std::concat!("condition failed: ", ::std::stringify!($cond))
            ));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    /// A two-level error chain for exercising `source()`/`Debug`.
    #[derive(Debug)]
    struct Leaf;
    impl fmt::Display for Leaf {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            f.write_str("leaf cause")
        }
    }
    impl StdError for Leaf {}

    #[derive(Debug)]
    struct Mid(Leaf);
    impl fmt::Display for Mid {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            f.write_str("mid failure")
        }
    }
    impl StdError for Mid {
        fn source(&self) -> Option<&(dyn StdError + 'static)> {
            Some(&self.0)
        }
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn source_is_the_next_level_down_like_real_anyhow() {
        let e = Error::new(Mid(Leaf));
        assert_eq!(e.to_string(), "mid failure");
        // source() skips the wrapped error itself (whose Display IS the
        // message) and returns its cause — real anyhow's deref behavior.
        assert_eq!(e.source().unwrap().to_string(), "leaf cause");
        // A message-only error has no source at all.
        assert!(Error::msg("plain").source().is_none());
    }

    #[test]
    fn anyhow_macro_formats() {
        let e = anyhow!("bad dim {} of {}", 3, 4);
        assert_eq!(e.to_string(), "bad dim 3 of 4");
    }

    #[test]
    fn bail_returns_err() {
        fn inner(flag: bool) -> Result<u32> {
            if flag {
                bail!("flagged {flag}");
            }
            Ok(7)
        }
        assert_eq!(inner(false).unwrap(), 7);
        assert_eq!(inner(true).unwrap_err().to_string(), "flagged true");
    }

    #[test]
    fn ensure_checks_condition() {
        fn inner(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            ensure!(x != 5);
            Ok(x)
        }
        assert_eq!(inner(3).unwrap(), 3);
        assert_eq!(inner(12).unwrap_err().to_string(), "x too big: 12");
        assert!(inner(5).unwrap_err().to_string().contains("x != 5"));
    }

    #[test]
    fn debug_prints_message_once_then_causes() {
        let e = Error::new(Mid(Leaf));
        let dbg = format!("{e:?}");
        assert!(dbg.contains("mid failure"), "{dbg}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("leaf cause"), "{dbg}");
        // The top-level message must not be duplicated into the chain.
        assert_eq!(dbg.matches("mid failure").count(), 1, "{dbg}");
    }
}

# DEEP-ER reproduction — build/verify entry points.
#
#   make verify     tier-1 gate: release build + full test suite
#   make build      release build only
#   make test       test suite only
#   make lint       rustfmt + clippy (advisory; requires the components)
#   make doc        rustdoc with broken-intra-doc-links denied via lib.rs
#   make figures    regenerate every paper exhibit (tables + figures)
#   make bench      run the micro/figure bench harnesses
#   make artifacts  AOT-lower the JAX/Pallas kernels to artifacts/*.hlo.txt
#                   (needs python + jax; optional — the rust stack degrades
#                   gracefully without it, see DESIGN.md)

CARGO ?= cargo

.PHONY: verify build test lint fmt clippy doc figures bench bench-smoke bench-scale bench-threads bench-fleet bench-qos bench-resilience bench-serve bench-obs bench-zoo artifacts clean

verify: build test

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q --workspace

lint: fmt clippy

fmt:
	$(CARGO) fmt --check

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

doc:
	$(CARGO) doc --no-deps

figures: build
	$(CARGO) run --release --bin repro -- bench all

bench:
	$(CARGO) bench --bench bench_sim_core
	$(CARGO) bench --bench bench_scr
	$(CARGO) bench --bench bench_io
	$(CARGO) bench --bench bench_figures

# What the CI bench-smoke job runs: every exhibit as CSV off the release
# binary, seed pinned so runs stay comparable across PRs.
bench-smoke: build
	$(CARGO) run --release --bin repro -- bench all --csv --seed 1 > bench-all.csv
	@echo "wrote bench-all.csv"

# Engine throughput sweep (1k/10k/100k concurrent flows) against the
# naive reference engine; refreshes the BENCH_sim_scale.json trajectory
# artifact with optimized + baseline numbers from THIS machine.
bench-scale: build
	$(CARGO) run --release --bin repro -- bench scale --csv --seed 1 --json BENCH_sim_scale.json
	@echo "wrote BENCH_sim_scale.json"

# Same sweep across the component-parallel engine's threads axis
# (DESIGN.md §14); the run self-checks that every thread count agrees
# with threads=1 on completion times before reporting anything, and the
# schema-v2 artifact records per-thread-count events/sec plus per-worker
# event counters.
bench-threads: build
	$(CARGO) run --release --bin repro -- bench scale --csv --seed 1 --threads 1,2,4 --json BENCH_sim_scale.json
	@echo "wrote BENCH_sim_scale.json (threads axis 1,2,4)"

# Fleet co-scheduling sweep (2/4/8/16 jobs under fcfs and backfill);
# refreshes the BENCH_fleet.json trajectory artifact.
bench-fleet: build
	$(CARGO) run --release --bin repro -- bench fleet --csv --seed 1 --json BENCH_fleet.json
	@echo "wrote BENCH_fleet.json"

# Traffic-class QoS exhibit: p99 exchange-phase slowdown under a
# neighbor's checkpoint flush, unshaped vs shaped; refreshes the
# BENCH_qos.json trajectory artifact.
bench-qos: build
	$(CARGO) run --release --bin repro -- bench qos --csv --seed 1 --json BENCH_qos.json
	@echo "wrote BENCH_qos.json"

# Degraded-mode resilience exhibit (DESIGN.md §15): the same co-scheduled
# mix under one correlated degrade-then-die fault schedule, reactive vs
# proactive; refreshes the BENCH_resilience.json trajectory artifact.
bench-resilience: build
	$(CARGO) run --release --bin repro -- bench resilience --csv --seed 1 --json BENCH_resilience.json
	@echo "wrote BENCH_resilience.json"

# Service-mode exhibit (DESIGN.md §16): an open-arrival Poisson stream
# through rolling admission on the incremental backfill profile, with
# per-window utilization and per-class p99 queue waits; refreshes the
# BENCH_serve.json trajectory artifact.
bench-serve: build
	$(CARGO) run --release --bin repro -- serve --arrivals poisson --rate 1 --jobs 2000 --seed 1 --json BENCH_serve.json
	@echo "wrote BENCH_serve.json"

# Observability exhibit (DESIGN.md §17): the same co-scheduled fleet
# with and without a trace installed — zero-perturbation check plus the
# tracing wall-time overhead; refreshes the BENCH_obs.json trajectory
# artifact and writes the Perfetto-loadable trace-fleet.json.
bench-obs: build
	$(CARGO) run --release --bin repro -- bench obs --csv --seed 1 --json BENCH_obs.json
	$(CARGO) run --release --bin repro -- fleet --jobs 8 --qos --seed 1 --trace-out trace-fleet.json
	@echo "wrote BENCH_obs.json trace-fleet.json"

# Topology-zoo variants of the qos and scale exhibits on the 2:1
# oversubscribed fat-tree (DESIGN.md §13); artifacts are written next to
# the flat-machine ones, never over them.
bench-zoo: build
	$(CARGO) run --release --bin repro -- bench qos --csv --seed 1 --topology fat-tree:2 --json BENCH_qos_fat-tree.json
	$(CARGO) run --release --bin repro -- bench scale --csv --seed 1 --topology fat-tree:2 --json BENCH_sim_scale_fat-tree.json
	@echo "wrote BENCH_qos_fat-tree.json BENCH_sim_scale_fat-tree.json"

artifacts:
	python3 python/compile/aot.py --out-dir artifacts

clean:
	$(CARGO) clean

//! End-to-end driver: REAL compute + simulated machine, all layers composed.
//!
//! This is the validation run demanded by DESIGN.md: the xPic particle
//! solver executes for real through the PJRT runtime (the AOT-lowered
//! JAX/Pallas `xpic_step` artifact — Boris push kernel included), while
//! checkpointing runs over the simulated DEEP-ER prototype with the
//! NAM XOR strategy.  Crucially the checkpoint *parity is also real*: the
//! `nam_parity` artifact (the Pallas XOR kernel modelling the NAM FPGA)
//! folds the actual state buffers, a node's state is dropped, and the
//! reconstruction is verified **bit-identical** before the run resumes.
//!
//! Python never runs here: both artifacts were lowered once by
//! `make artifacts`.
//!
//!     cargo run --release --example e2e_xpic_pjrt
//!
//! Output: per-phase diagnostics (field energy trace = the "loss curve" of
//! this workload), checkpoint/restart timings in virtual time, and the
//! bit-exactness verdict.  The sim-vs-real boundary this example
//! exercises is documented in DESIGN.md section 3.

use deeper::runtime::{default_artifacts_dir, Runtime, Tensor};
use deeper::scr::{Scr, Strategy};
use deeper::system::{presets, Machine, NodeKind};

const ITERS: usize = 100;
const CP_EVERY: usize = 10;
const FAIL_AT: usize = 60;
const FAIL_NODE: usize = 3;

/// Simulated nodes each own one shard of the real particle state.
const SHARDS: usize = 8;

fn f32s(t: &Tensor) -> &[f32] {
    t.as_f32().expect("f32 tensor")
}

/// Pack a node's state shard into i32 words for the parity engine
/// (bit-preserving reinterpretation, padded to the parity width).
fn pack_shard(x: &[f32], v: &[f32], words: usize) -> Vec<i32> {
    let mut out = Vec::with_capacity(words);
    out.extend(x.iter().map(|f| f.to_bits() as i32));
    out.extend(v.iter().map(|f| f.to_bits() as i32));
    assert!(out.len() <= words, "shard exceeds parity width");
    out.resize(words, 0);
    out
}

fn main() -> anyhow::Result<()> {
    println!("=== DEEP-ER e2e: real xPic compute (PJRT) + NAM XOR checkpointing (DES) ===");
    let mut rt = Runtime::open(default_artifacts_dir())?;
    let xpic = rt.spec("xpic_step").expect("xpic_step artifact").clone();
    let parity_spec = rt.spec("nam_parity").expect("nam_parity artifact").clone();
    let p = xpic.inputs[0].shape[0]; // particles
    let g3 = xpic.inputs[2].shape[0]; // grid cells
    let parity_n = parity_spec.inputs[0].shape[0];
    let parity_m = parity_spec.inputs[0].shape[1];
    assert_eq!(parity_n, SHARDS, "parity artifact is shaped for 8 nodes");
    println!("particles={p}  grid cells={g3}  parity block={parity_m} x i32");

    // --- real state -------------------------------------------------------
    let mut rng = deeper::sim::rng::SplitMix64::new(42);
    let mut x: Vec<f32> = (0..p * 3).map(|_| rng.next_f64() as f32).collect();
    let mut v: Vec<f32> = (0..p * 3).map(|_| (rng.next_f64() as f32 - 0.5) * 0.02).collect();
    let mut e: Vec<f32> = (0..g3 * 3).map(|_| (rng.next_f64() as f32 - 0.5) * 0.2).collect();
    let b: Vec<f32> = vec![0.05; g3 * 3];

    // --- simulated machine + SCR ------------------------------------------
    let mut m = Machine::build(presets::deep_er());
    let nodes: Vec<usize> = m.nodes_of(NodeKind::Cluster).into_iter().take(SHARDS).collect();
    let mut scr = Scr::new(Strategy::NamXor);
    // Real per-node payload: one shard of x+v (f32) padded to parity width.
    let shard_particles = p / SHARDS;
    let shard_bytes = (parity_m * 4) as f64;

    let mut energy_trace: Vec<(usize, f32, f32)> = Vec::new();
    let mut ckpt_shards: Vec<Vec<i32>> = Vec::new();
    let mut parity: Vec<i32> = Vec::new();
    let mut ckpt_iter = 0usize;
    let mut failed_once = false;
    let mut compute_wall = 0.0f64;

    let mut it = 0usize;
    while it < ITERS {
        // ----- failure injection + REAL reconstruction -----
        if it == FAIL_AT && !failed_once {
            failed_once = true;
            println!("--- node {FAIL_NODE} fails at iteration {it} ---");
            m.kill_node(nodes[FAIL_NODE]);
            // Survivors + NAM parity rebuild the lost shard, for real:
            let mut rebuilt = parity.clone();
            for (i, shard) in ckpt_shards.iter().enumerate() {
                if i != FAIL_NODE {
                    for (r, s) in rebuilt.iter_mut().zip(shard) {
                        *r ^= *s;
                    }
                }
            }
            assert_eq!(
                rebuilt, ckpt_shards[FAIL_NODE],
                "parity reconstruction must be bit-identical"
            );
            println!("    parity reconstruction: bit-identical OK");
            // Restore the full real state from the checkpoint shards.
            for (i, shard) in ckpt_shards.iter().enumerate() {
                let base = i * shard_particles * 3;
                for k in 0..shard_particles * 3 {
                    x[base + k] = f32::from_bits(shard[k] as u32);
                    v[base + k] = f32::from_bits(shard[shard_particles * 3 + k] as u32);
                }
            }
            // Simulated restart cost on the machine.
            m.revive_node(nodes[FAIL_NODE]);
            let r = scr.restart(&mut m, &nodes, Some(nodes[FAIL_NODE]))?;
            println!(
                "    simulated restart: {:.2} s virtual (rebuilt={})",
                r.time, r.rebuilt
            );
            it = ckpt_iter; // roll back to the checkpointed iteration
            continue;
        }

        // ----- REAL compute through PJRT -----
        let t0 = std::time::Instant::now();
        let out = rt.execute(
            "xpic_step",
            &[
                Tensor::F32 { shape: vec![p, 3], data: x.clone() },
                Tensor::F32 { shape: vec![p, 3], data: v.clone() },
                Tensor::F32 { shape: vec![g3, 3], data: e.clone() },
                Tensor::F32 { shape: vec![g3, 3], data: b.clone() },
            ],
        )?;
        compute_wall += t0.elapsed().as_secs_f64();
        x = f32s(&out[0]).to_vec();
        v = f32s(&out[1]).to_vec();
        e = f32s(&out[2]).to_vec();
        let rho = f32s(&out[3]);

        // Simulated compute phase keeps virtual time honest.
        let flows: Vec<_> = nodes
            .iter()
            .map(|&n| m.compute(n, 1.8e12 / SHARDS as f64, 0.08))
            .collect();
        m.sim.wait_all(&flows);

        it += 1;
        if it % 10 == 0 {
            let ke: f32 = v.iter().map(|a| a * a).sum::<f32>() * 0.5;
            let fe: f32 = e.iter().map(|a| a * a).sum::<f32>() * 0.5;
            energy_trace.push((it, ke, fe));
            let total_rho: f32 = rho.iter().sum();
            println!("iter {it:>3}: kinetic={ke:>10.3}  field={fe:>9.4}  charge={total_rho:.0}");
        }

        // ----- checkpoint: real shards + REAL parity through PJRT -----
        if it % CP_EVERY == 0 && it < ITERS {
            ckpt_shards = (0..SHARDS)
                .map(|i| {
                    let base = i * shard_particles * 3;
                    pack_shard(
                        &x[base..base + shard_particles * 3],
                        &v[base..base + shard_particles * 3],
                        parity_m,
                    )
                })
                .collect();
            let blocks: Vec<i32> = ckpt_shards.iter().flatten().copied().collect();
            let pout = rt.execute(
                "nam_parity",
                &[Tensor::I32 { shape: vec![SHARDS, parity_m], data: blocks }],
            )?;
            parity = pout[0].as_i32().unwrap().to_vec();
            let rep = scr.checkpoint(&mut m, &nodes, shard_bytes)?;
            ckpt_iter = it;
            if it == CP_EVERY {
                println!(
                    "checkpoint @ {it}: {:.1} MB/node, blocked {:.3} s virtual, {:.2} GB/s",
                    shard_bytes / 1e6,
                    rep.blocked,
                    rep.bandwidth / 1e9
                );
            }
        }
    }

    println!("--- run complete ---");
    println!("iterations        : {ITERS} (+ rollback re-execution)");
    println!("virtual time      : {:.1} s", m.sim.now());
    println!("real compute wall : {compute_wall:.1} s (PJRT, CPU)");
    println!("checkpoints       : {}", scr.database().len());
    println!("energy trace (iter, kinetic, field):");
    for (i, ke, fe) in &energy_trace {
        println!("  {i:>4} {ke:>12.3} {fe:>10.4}");
    }
    let last = energy_trace.last().unwrap();
    anyhow::ensure!(last.1.is_finite() && last.2.is_finite(), "state blew up");
    println!("e2e OK: all layers composed (Pallas kernel -> JAX step -> HLO -> PJRT -> rust SCR/NAM)");
    Ok(())
}

//! FWI + OmpSs resiliency — the Fig. 10 scenario across all four
//! resilience modes and both failure positions ("worker or slave").
//!
//! The FWI inversion is an OmpSs task graph (frequency cycles of per-shot
//! propagations + gradient updates) offloaded over ParaStation MPI.  A
//! failure is injected either in a *worker* shot task right before the
//! end, or in an earlier *slave* task mid-run, matching the two error
//! bars of the paper's figure.
//!
//!     cargo run --release --example fwi_resilient_offload

use deeper::apps::fwi;
use deeper::ompss::{OmpssRuntime, Resilience};
use deeper::system::failure::FailurePlan;
use deeper::system::{presets, Machine};

fn main() {
    let graph = fwi::task_graph(5, 4, 3e11);
    let workers: Vec<usize> = (1..5).collect();
    let last = fwi::last_task(&graph);
    let mid = last / 2;

    let run = |res: Resilience, failures: &FailurePlan| -> f64 {
        let mut m = Machine::build(presets::marenostrum3());
        OmpssRuntime::new(0, res)
            .execute(&mut m, &graph, &workers, failures)
            .time
    };

    let clean = run(Resilience::None, &FailurePlan::none());
    println!("FWI inversion: {} tasks on {} workers (MareNostrum 3)", graph.tasks.len(), workers.len());
    println!("clean run (no resiliency): {clean:.1} s\n");

    println!(
        "{:<28} {:>14} {:>14} {:>12}",
        "mode", "err@worker s", "err@slave s", "overhead %"
    );
    for res in [
        Resilience::None,
        Resilience::Lightweight,
        Resilience::Persistent,
        Resilience::ResilientOffload,
    ] {
        let t_clean = run(res, &FailurePlan::none());
        let t_worker = run(res, &FailurePlan::one_at_iteration(0, last));
        let t_slave = run(res, &FailurePlan::one_at_iteration(0, mid));
        println!(
            "{:<28} {t_worker:>14.1} {t_slave:>14.1} {:>11.2}%",
            res.name(),
            (t_clean / clean - 1.0) * 100.0
        );
    }

    let t_none = run(Resilience::None, &FailurePlan::one_at_iteration(0, last));
    let t_res = run(
        Resilience::ResilientOffload,
        &FailurePlan::one_at_iteration(0, last),
    );
    println!(
        "\nlate failure: unprotected {:.1}x clean; resilient offload saves {:.0}% (paper: ~42%)",
        t_none / clean,
        (1.0 - t_res / t_none) * 100.0
    );
    println!("fwi_resilient_offload OK");
}

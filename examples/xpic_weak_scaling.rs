//! xPic weak-scaling I/O study — the Fig. 6 + Fig. 7 scenarios as a
//! configurable driver.
//!
//! Sweeps node counts on two testbeds:
//! * QPACE3 (672x KNL): global BeeGFS vs BeeOND-on-RAM-disk (Fig. 6),
//!   including the derived application-level speedup the paper quotes
//!   as ~7x at full scale.
//! * DEEP-ER Cluster: node-local NVMe vs node-local HDD (Fig. 7).
//!
//!     cargo run --release --example xpic_weak_scaling [-- --max-nodes 672]

use deeper::apps::xpic;
use deeper::beegfs::beeond::{concurrent_cache_write, concurrent_global_write, CacheDevice};
use deeper::beegfs::{BeeOnd, CacheMode};
use deeper::system::{presets, Machine};
use deeper::util::cli::Args;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let max_nodes = args.get_usize("max-nodes", 672);

    // ---------------- Fig. 6: QPACE3 ----------------
    println!("== xPic on QPACE3: 10 GB/node, global BeeGFS vs BeeOND (RAM-disk) ==");
    println!(
        "{:>7} {:>14} {:>14} {:>12} {:>12}",
        "nodes", "global s", "local s", "IO speedup", "app speedup"
    );
    let bytes = xpic::profile_qpace3().ckpt_bytes_per_node;
    // Compute phase per I/O phase, from the xPic QPACE3 profile: the
    // app-level speedup depends on how much compute amortizes the I/O.
    let p = xpic::profile_qpace3();
    let compute_phase = 10.0 * p.flops_per_iter_per_node / (2.5e12 * p.cpu_efficiency) * 0.031;
    for &n in &[16usize, 32, 64, 128, 256, 512, 672] {
        if n > max_nodes {
            break;
        }
        let nodes: Vec<usize> = (0..n).collect();
        let mut m1 = Machine::build(presets::qpace3().with_cluster_nodes(n));
        let t_global = concurrent_global_write(&mut m1, &nodes, bytes);
        let mut m2 = Machine::build(presets::qpace3().with_cluster_nodes(n));
        let mut cache = BeeOnd::new(CacheDevice::RamDisk, CacheMode::Async);
        let t_local = concurrent_cache_write(&mut m2, &mut cache, &nodes, bytes, 64);
        let app_speedup = (compute_phase + t_global) / (compute_phase + t_local);
        println!(
            "{n:>7} {t_global:>14.3} {t_local:>14.3} {:>11.1}x {:>11.1}x",
            t_global / t_local,
            app_speedup
        );
    }

    // ---------------- Fig. 7: DEEP-ER NVMe vs HDD ----------------
    println!();
    println!("== xPic on DEEP-ER Cluster: 8 GB, node-local NVMe vs HDD ==");
    println!("{:>7} {:>12} {:>12} {:>10}", "nodes", "NVMe s", "HDD s", "speedup");
    let bytes = xpic::profile_deep_er().ckpt_bytes_per_node;
    for &n in &[1usize, 2, 4, 8, 16] {
        let nodes: Vec<usize> = (0..n).collect();
        let mut m1 = Machine::build(presets::deep_er());
        let mut c1 = BeeOnd::new(CacheDevice::Nvme, CacheMode::Async);
        let t_nvme = concurrent_cache_write(&mut m1, &mut c1, &nodes, bytes, 24);
        let mut m2 = Machine::build(presets::deep_er());
        let mut c2 = BeeOnd::new(CacheDevice::Hdd, CacheMode::Async);
        let t_hdd = concurrent_cache_write(&mut m2, &mut c2, &nodes, bytes, 24);
        println!("{n:>7} {t_nvme:>12.2} {t_hdd:>12.2} {:>9.1}x", t_hdd / t_nvme);
    }
    println!("xpic_weak_scaling OK");
}

//! The full co-design portfolio: all seven DEEP-ER applications through
//! the same stack (paper Section IV — "the typically broad user
//! portfolio of a large-scale HPC center").
//!
//! Each app runs 20 iterations on 8 Cluster nodes with Buddy checkpoints
//! every 5 and one injected node failure, and reports its cost structure
//! — which is exactly where the portfolio earns its keep: SKA is
//! checkpoint-dominated, TurboRvB compute-dominated, CHROMA pays the
//! collective latency, and the three headline apps sit in between.
//!
//!     cargo run --release --example portfolio

use deeper::apps::{portfolio, run_iterations, IterationJob};
use deeper::scr::{Scr, Strategy};
use deeper::system::failure::FailurePlan;
use deeper::system::{presets, Machine, NodeKind};

fn main() {
    println!(
        "{:<14} {:>9} {:>9} {:>9} {:>9} {:>9} {:>7}",
        "app", "total s", "compute", "exchange", "ckpt", "restart", "ckpt %"
    );
    for profile in portfolio::all_seven() {
        let mut m = Machine::build(presets::deep_er());
        let nodes: Vec<usize> = m.nodes_of(NodeKind::Cluster).into_iter().take(8).collect();
        let job = IterationJob {
            profile: profile.clone(),
            iterations: 20,
            cp_interval: 5,
            failures: FailurePlan::one_at_iteration(3, 12),
        };
        let mut scr = Scr::new(Strategy::Buddy);
        let stats = run_iterations(&mut m, &nodes, &job, Some(&mut scr));
        println!(
            "{:<14} {:>9.1} {:>9.1} {:>9.2} {:>9.1} {:>9.2} {:>6.1}%",
            profile.name,
            stats.total_time,
            stats.compute_time,
            stats.exchange_time,
            stats.ckpt_time,
            stats.restart_time,
            stats.ckpt_overhead() * 100.0
        );
    }

    // CHROMA's defining pattern deserves its own line: latency-coupled CG.
    let mut m = Machine::build(presets::deep_er());
    let nodes = m.nodes_of(NodeKind::Cluster);
    let t = portfolio::chroma_solver_phase(&mut m, &nodes, 100);
    println!("\nchroma CG phase: 100 coupled inner steps on 16 nodes = {t:.2} s");
    println!("portfolio OK");
}

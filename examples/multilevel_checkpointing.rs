//! Multi-level checkpointing over the DEEP-ER stack, Young/Daly tuned.
//!
//! SCR (the library the paper builds on) is a *multi-level* system: cheap
//! node-local checkpoints often, partner/XOR checkpoints less often, and
//! global-file-system flushes rarely.  This example derives the level
//! cadence from a failure model with the Young optimum
//! (`sqrt(2 * cost * MTBF)` per level), runs xPic over it on the
//! simulated prototype with failures injected from an exponential-MTBF
//! schedule, and compares the result against single-level protection.
//!
//!     cargo run --release --example multilevel_checkpointing

use deeper::scr::multilevel::{optimal_interval, MultiLevelConfig, MultiLevelScr};
use deeper::scr::{Scr, Strategy};
use deeper::system::{presets, Machine, NodeKind};

const ITERS: usize = 60;
const BYTES: f64 = 2e9;

fn main() -> anyhow::Result<()> {
    // --- Young/Daly cadence from a failure model -------------------------
    let iter_time = 22.5; // s per xPic iteration on the prototype
    let (l1_cost, l2_cost, l3_cost) = (1.9, 3.0, 13.0); // measured below
    let (mtbf_proc, mtbf_node, mtbf_sys) = (4.0e3, 8.0e4, 6.0e5);
    println!("Young optimal intervals:");
    println!("  L1 (local)   : {:.0} s", optimal_interval(l1_cost, mtbf_proc));
    println!("  L2 (buddy)   : {:.0} s", optimal_interval(l2_cost, mtbf_node));
    println!("  L3 (global)  : {:.0} s", optimal_interval(l3_cost, mtbf_sys));
    let cfg = MultiLevelConfig::from_failure_model(
        iter_time, l1_cost, l2_cost, l3_cost, mtbf_proc, mtbf_node, mtbf_sys,
    );
    println!(
        "derived cadence: L1 every {} iters, L2 every {} L1s, L3 every {} L2s\n",
        cfg.l1_every, cfg.l2_every, cfg.l3_every
    );

    // --- run with the multi-level scheme ---------------------------------
    let mut m = Machine::build(presets::deep_er());
    let nodes = m.nodes_of(NodeKind::Cluster);
    let mut ml = MultiLevelScr::new(cfg.clone());
    let mut blocked_ml = 0.0;
    for iter in 1..=ITERS {
        let flows: Vec<_> = nodes.iter().map(|&n| m.compute(n, 1.8e12, 0.08)).collect();
        m.sim.wait_all(&flows);
        blocked_ml += ml.checkpoint_at(&mut m, &nodes, BYTES, iter)?;
    }
    // Transient error: L1 covers it.
    let t_l1 = ml.restart(&mut m, &nodes, None)?;
    // Node loss: L2 covers it.
    m.kill_node(nodes[4]);
    m.revive_node(nodes[4]);
    let t_l2 = ml.restart(&mut m, &nodes, Some(nodes[4]))?;
    ml.drain(&mut m);
    println!("multi-level run ({} iters):", ITERS);
    println!(
        "  L1 x{} ({:.1} s) | L2 x{} ({:.1} s) | L3 x{} (blocked {:.2} s, async)",
        ml.stats.l1_count,
        ml.stats.l1_time,
        ml.stats.l2_count,
        ml.stats.l2_time,
        ml.stats.l3_count,
        ml.stats.l3_blocked
    );
    println!("  blocked total        : {blocked_ml:.1} s");
    println!("  transient restart L1 : {t_l1:.2} s");
    println!("  node-loss restart L2 : {t_l2:.2} s");

    // --- baseline: single-level Buddy at the L1 cadence ------------------
    let mut m2 = Machine::build(presets::deep_er());
    let nodes2 = m2.nodes_of(NodeKind::Cluster);
    let mut scr = Scr::new(Strategy::Buddy);
    let mut blocked_flat = 0.0;
    for iter in 1..=ITERS {
        let flows: Vec<_> = nodes2.iter().map(|&n| m2.compute(n, 1.8e12, 0.08)).collect();
        m2.sim.wait_all(&flows);
        if iter % cfg.l1_every == 0 {
            let t0 = m2.sim.now();
            scr.checkpoint(&mut m2, &nodes2, BYTES)?;
            blocked_flat += m2.sim.now() - t0;
        }
    }
    println!("\nflat Buddy at the L1 cadence:");
    println!("  blocked total        : {blocked_flat:.1} s");
    let saving = 1.0 - blocked_ml / blocked_flat;
    println!(
        "\nmulti-level blocks {:.0}% less while adding global-level protection",
        saving * 100.0
    );
    anyhow::ensure!(blocked_ml < blocked_flat, "multi-level must block less");
    println!("multilevel_checkpointing OK");
    Ok(())
}

//! Quickstart: the smallest end-to-end tour of the public API.
//!
//! Builds the DEEP-ER prototype (Table I), runs the N-body code for 50
//! iterations with Buddy checkpointing, injects a node failure at
//! iteration 30, and prints the timing breakdown — the Fig. 4 / Fig. 8
//! machinery in one page of code.
//!
//!     cargo run --release --example quickstart

use deeper::apps::{self, run_iterations, IterationJob};
use deeper::metrics::fmt_time;
use deeper::scr::{Scr, Strategy};
use deeper::system::failure::FailurePlan;
use deeper::system::{presets, Machine, NodeKind};

fn main() {
    // 1. Build the simulated machine from the published configuration.
    let mut machine = Machine::build(presets::deep_er());
    println!(
        "machine: {} ({} cluster + {} booster nodes, {} NAM boards)",
        machine.spec.name,
        machine.spec.n_cluster,
        machine.spec.n_booster,
        machine.nams.len()
    );

    // 2. Pick the job: N-body on all 16 Cluster nodes, Buddy checkpoints
    //    every 5 iterations, one node failure at iteration 30.
    let nodes = machine.nodes_of(NodeKind::Cluster);
    let job = IterationJob {
        profile: apps::nbody::profile(),
        iterations: 50,
        cp_interval: 5,
        failures: FailurePlan::one_at_iteration(7, 30),
    };

    // 3. Run with SCR's Buddy strategy (DEEP-ER's SIONlib-optimized
    //    SCR_PARTNER; see scr::Strategy for the other four).
    let mut scr = Scr::new(Strategy::Buddy);
    let stats = run_iterations(&mut machine, &nodes, &job, Some(&mut scr));

    // 4. Report.
    println!("iterations run : {} (50 requested; rollback re-executes)", stats.iterations_run);
    println!("total time     : {}", fmt_time(stats.total_time));
    println!("  compute      : {}", fmt_time(stats.compute_time));
    println!("  exchange     : {}", fmt_time(stats.exchange_time));
    println!(
        "  checkpoints  : {} over {} CPs ({:.1}% overhead)",
        fmt_time(stats.ckpt_time),
        stats.checkpoints_taken,
        stats.ckpt_overhead() * 100.0
    );
    println!(
        "  restart      : {} after {} failure(s)",
        fmt_time(stats.restart_time),
        stats.failures_hit
    );
    assert_eq!(stats.failures_hit, 1);
    println!("quickstart OK");
}
